//! Decision-latency bench (paper §IV-E): per-invocation cost of each
//! policy's decide(), including both LACE-RL inference paths.
//!
//! Paper claims: DQN inference ≈ 15 µs/invocation; DPSO ≈ 4,600× slower.
//! Here we report: native Rust MLP, PJRT (AOT Pallas kernel), PJRT
//! (pure-jnp ablation), DPSO, and the trivial baselines.

use lace_rl::policy::dpso::{Dpso, DpsoConfig};
use lace_rl::policy::lace_rl::{LaceRlPolicy, PjrtQ};
use lace_rl::policy::native_mlp::NativeMlp;
use lace_rl::policy::{
    CarbonMin, DecisionContext, FixedTimeout, KeepAlivePolicy, LatencyMin,
};
use lace_rl::runtime::{artifacts, ArtifactSet, PjrtRuntime, QNetInfer};
use lace_rl::trace::model::{FunctionProfile, Runtime, TriggerType};
use lace_rl::util::bench::{bench, black_box};

fn profile() -> FunctionProfile {
    FunctionProfile {
        id: 0,
        runtime: Runtime::Custom,
        trigger: TriggerType::Http,
        mem_mb: 128.0,
        cpu_cores: 1.0,
        cold_start_s: 4.5,
        mean_exec_s: 0.8,
    }
}

fn main() -> anyhow::Result<()> {
    println!("== decision latency (per policy decide() call) ==\n");
    let prof = profile();
    let ctx = DecisionContext {
        t: 1234.5,
        func: &prof,
        ci: 420.0,
        reuse_probs: [0.15, 0.35, 0.55, 0.8, 0.92],
        lambda_carbon: 0.5,
        idle_power_w: 1.25,
        next_arrival_gap: None,
    };

    let mut fixed = FixedTimeout::huawei();
    bench("fixed-60s/decide", || {
        black_box(fixed.decide(black_box(&ctx)));
    });
    let mut lat = LatencyMin;
    bench("latency-min/decide", || {
        black_box(lat.decide(black_box(&ctx)));
    });
    let mut car = CarbonMin;
    bench("carbon-min/decide", || {
        black_box(car.decide(black_box(&ctx)));
    });

    // LACE-RL native fast path.
    let art = ArtifactSet::open(&artifacts::default_dir())?;
    let params = art.best_params()?;
    let mut lace_native = LaceRlPolicy::new(NativeMlp::new(params.clone()));
    let native = bench("lace-rl(native)/decide", || {
        black_box(lace_native.decide(black_box(&ctx)));
    });

    // LACE-RL AOT paths via PJRT.
    let runtime = PjrtRuntime::cpu()?;
    let dims = art.manifest.dims();
    let mut lace_pjrt = LaceRlPolicy::new(PjrtQ::new(
        QNetInfer::new(runtime.load_hlo_text(art.infer_path(1).to_str().unwrap())?, 1, dims),
        params.clone(),
    ));
    let pjrt = bench("lace-rl(pjrt-pallas)/decide", || {
        black_box(lace_pjrt.decide(black_box(&ctx)));
    });
    let mut lace_jnp = LaceRlPolicy::new(PjrtQ::new(
        QNetInfer::new(
            runtime.load_hlo_text(art.infer_jnp_path(1).to_str().unwrap())?,
            1,
            dims,
        ),
        params.clone(),
    ));
    let jnp = bench("lace-rl(pjrt-jnp)/decide", || {
        black_box(lace_jnp.decide(black_box(&ctx)));
    });

    // Batched PJRT inference amortization (256 states per dispatch).
    let infer256 = QNetInfer::new(
        runtime.load_hlo_text(art.infer_path(256).to_str().unwrap())?,
        256,
        dims,
    );
    let states: Vec<f32> = (0..256 * dims.0).map(|i| (i % 17) as f32 * 0.05).collect();
    let b256 = bench("lace-rl(pjrt-pallas)/batch256", || {
        black_box(infer256.q_values(&params, &states).unwrap());
    });
    println!(
        "  -> batched PJRT per-state cost: {:.2}µs",
        b256.median_ns / 256.0 / 1_000.0
    );

    // DPSO.
    let mut dpso = Dpso::new(DpsoConfig::default());
    let d = bench("dpso-ecolife/decide", || {
        black_box(dpso.decide(black_box(&ctx)));
    });

    println!("\n== ratios ==");
    println!("dpso / lace-rl(native):      {:.0}x", d.median_ns / native.median_ns);
    println!("dpso / lace-rl(pjrt-pallas): {:.2}x", d.median_ns / pjrt.median_ns);
    println!("pjrt-pallas / native:        {:.0}x (interpret-mode Pallas + dispatch overhead)",
        pjrt.median_ns / native.median_ns);
    println!("pjrt-jnp / native:           {:.0}x (dispatch overhead only)",
        jnp.median_ns / native.median_ns);
    Ok(())
}
