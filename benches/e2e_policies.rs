//! End-to-end policy benchmark: wall-clock of the full Fig. 5 / Fig. 8
//! evaluation runs (one per paper table), plus the online coordinator
//! serving throughput. These are the end-to-end numbers EXPERIMENTS.md
//! §Perf tracks across optimization iterations.

use lace_rl::coordinator::driver::Pace;
use lace_rl::coordinator::{CoordinatorServer, RouterConfig};
use lace_rl::experiments::workload;
use lace_rl::policy::dpso::{Dpso, DpsoConfig};
use lace_rl::policy::{CarbonMin, FixedTimeout, KeepAlivePolicy, LatencyMin};
use lace_rl::util::bench::bench_once;

fn main() -> anyhow::Result<()> {
    let w = workload::build(7, true); // quick-scale workload for benching
    println!(
        "== e2e policy runs (General: {} invocations, Long-tailed: {}) ==\n",
        w.general.len(),
        w.long_tailed.len()
    );

    let mut run = |label: &str, policy: &mut dyn KeepAlivePolicy, long: bool| {
        let trace = if long { &w.long_tailed } else { &w.general };
        bench_once(label, 3, || {
            workload::evaluate(trace, &w.ci, &w.energy, policy, 0.5, false);
        });
    };

    // Fig. 5 rows (General workload).
    run("fig5/latency-min", &mut LatencyMin, false);
    run("fig5/carbon-min", &mut CarbonMin, false);
    run("fig5/huawei-60s", &mut FixedTimeout::huawei(), false);
    run("fig5/dpso-ecolife", &mut Dpso::new(DpsoConfig::default()), false);
    let mut lace = workload::lace_rl_policy()?;
    run("fig5/lace-rl", &mut lace, false);

    // Fig. 8 rows (Long-tailed workload).
    run("fig8/huawei-60s", &mut FixedTimeout::huawei(), true);
    let mut lace = workload::lace_rl_policy()?;
    run("fig8/lace-rl", &mut lace, true);

    // Online coordinator serving throughput.
    println!("\n== online coordinator (threaded driver -> router) ==\n");
    let (report, _) = CoordinatorServer::run(
        &w.general,
        FixedTimeout::huawei(),
        w.ci.clone(),
        w.energy.clone(),
        RouterConfig::default(),
        Pace::MaxSpeed,
        1024,
    )?;
    println!(
        "serve/fixed-60s: {:.0} req/s over {} requests (decision mean {:.2}µs)",
        report.throughput_rps, report.requests, report.mean_decision_us
    );
    let (report, _) = CoordinatorServer::run(
        &w.general,
        workload::lace_rl_policy()?,
        w.ci.clone(),
        w.energy.clone(),
        RouterConfig::default(),
        Pace::MaxSpeed,
        1024,
    )?;
    println!(
        "serve/lace-rl:   {:.0} req/s over {} requests (decision mean {:.2}µs)",
        report.throughput_rps, report.requests, report.mean_decision_us
    );
    Ok(())
}
