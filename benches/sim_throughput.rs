//! Simulator/coordinator hot-path throughput: invocations simulated per
//! second per policy, plus microbenchmarks of the per-invocation pieces
//! (state encode, reuse-window probs, CI integration) and the parallel
//! sweep harness speedup.
//!
//! This is the L3 perf-pass measurement target (DESIGN.md §8): ≥1M
//! simulated invocations/s with a trivial policy; the native-DQN run shows
//! the policy overhead on top.
//!
//! Every policy run constructs a **fresh** policy per timed iteration via a
//! factory — stateful policies (LACE-RL reuse windows/observations) would
//! otherwise warm up across iterations and skew the median.
//!
//! Writes `BENCH_sim.json` (median ns + invocations/s per label) so
//! `scripts/bench_smoke.sh` can track the perf trajectory across PRs.
//! Pass `--smoke` for a shrunken workload (CI-scale).

use std::time::Instant;

use lace_rl::carbon::intensity::CarbonTrace;
use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::energy::model::EnergyModel;
use lace_rl::experiments::workload;
use lace_rl::policy::{CarbonMin, FixedTimeout, KeepAlivePolicy, LatencyMin};
use lace_rl::simulator::engine::{SimConfig, Simulator};
use lace_rl::simulator::parallel::{BoxedPolicy, SweepCell, SweepRunner};
use lace_rl::simulator::reuse::ReuseWindow;
use lace_rl::simulator::sharded::ShardedSimulator;
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::util::bench::{bench, bench_once, black_box, Report};

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("== simulator throughput{} ==\n", if smoke { " (smoke)" } else { "" });
    let cfg = if smoke {
        SynthConfig {
            n_functions: 60,
            duration_s: 1800.0,
            target_invocations: 30_000,
            seed: 7,
            ..SynthConfig::default()
        }
    } else {
        SynthConfig {
            n_functions: 200,
            duration_s: 7200.0,
            target_invocations: 200_000,
            seed: 7,
            ..SynthConfig::default()
        }
    };
    let trace = TraceGenerator::new(cfg).generate();
    let n = trace.len() as f64;
    println!("workload: {} invocations\n", trace.len());
    let ci = synth_region(Region::SolarHeavy, 1, 7);
    let energy = EnergyModel::default();
    let samples = if smoke { 3 } else { 5 };
    let mut report = Report::new();

    {
        let mut run_policy = |label: &str, factory: &dyn Fn() -> Box<dyn KeepAlivePolicy>| {
            let sim = Simulator::new(&trace, &ci, energy.clone(), SimConfig::default());
            let s = bench_once(label, samples, || {
                // Fresh policy per iteration: no cross-iteration state.
                let mut policy = factory();
                black_box(sim.run(policy.as_mut()).metrics.cold_starts);
            });
            println!("  -> {:.2}M invocations/s\n", n / (s.median_ns / 1e9) / 1e6);
            report.add(s);
        };

        run_policy("sim/fixed-60s", &|| Box::new(FixedTimeout::huawei()));
        run_policy("sim/carbon-min", &|| Box::new(CarbonMin));
        match workload::lace_rl_params() {
            Ok(params) => {
                run_policy("sim/lace-rl-native", &move || {
                    Box::new(workload::lace_rl_from_params(&params))
                });
            }
            Err(e) => println!("(skipping sim/lace-rl-native: no artifacts — {e})\n"),
        }
    }

    // Parallel sweep harness: wall-clock of an 8-cell fixed-timeout sweep,
    // sequential (1 thread) vs all cores.
    println!("== parallel sweep (8 cells) ==\n");
    let make_cells = || -> Vec<SweepCell> {
        (0..8)
            .map(|i| {
                let secs = 1.0 + i as f64 * 8.0;
                SweepCell::new(format!("fixed-{secs}"), SimConfig::default(), move || {
                    Box::new(FixedTimeout::new(secs)) as BoxedPolicy
                })
            })
            .collect()
    };
    let seq_runner = SweepRunner::new(&trace, &ci, energy.clone()).with_threads(1);
    let t0 = Instant::now();
    black_box(seq_runner.run(make_cells()).len());
    let seq_s = t0.elapsed().as_secs_f64();
    let par_runner = SweepRunner::new(&trace, &ci, energy.clone());
    let t0 = Instant::now();
    black_box(par_runner.run(make_cells()).len());
    let par_s = t0.elapsed().as_secs_f64();
    println!(
        "sweep/8-cells: sequential {seq_s:.3}s, parallel {par_s:.3}s on {} threads  -> {:.2}x speedup\n",
        par_runner.threads(),
        seq_s / par_s.max(1e-12),
    );

    // Function-sharded single run: the *same* one-trace replay split across
    // cores (simulator::sharded). k=1 runs the identical sequential path,
    // so the ratio isolates the sharding win; output is bit-identical at
    // every k (tests/property_sharded.rs), making this a pure speedup.
    println!("== sharded single run (fixed-60s) ==\n");
    let mut base_ns = 0.0f64;
    for k in [1usize, 2, 4, 8] {
        let sim = ShardedSimulator::new(&trace, &ci, energy.clone(), SimConfig::default())
            .with_shards(k);
        let s = bench_once(&format!("sharded/fixed-60s-{k}shards"), samples, || {
            let mut policy = FixedTimeout::huawei();
            black_box(sim.run(&mut policy).metrics.cold_starts);
        });
        if k == 1 {
            base_ns = s.median_ns;
        }
        println!(
            "  -> {:.2}M invocations/s, {:.2}x vs 1 shard\n",
            n / (s.median_ns / 1e9) / 1e6,
            base_ns / s.median_ns.max(1e-9),
        );
        report.add(s);
    }

    // Telemetry overhead: the same sequential fixed-60s run with obs
    // collection forced on (per-run SimConfig flag; the global sink stays
    // uninstalled so the rest of this bench is unaffected). The delta vs
    // `sim/fixed-60s` is the enabled-collection cost; the disabled cost is
    // zero by construction (a branch over a constant-false flag) and is
    // regression-gated against BENCH_sim.json by scripts/bench_smoke.sh.
    println!("== obs collection overhead (fixed-60s) ==\n");
    {
        let obs_cfg = SimConfig { collect_obs: true, ..SimConfig::default() };
        let sim = Simulator::new(&trace, &ci, energy.clone(), obs_cfg);
        let s = bench_once("sim/fixed-60s-obs", samples, || {
            let mut policy = FixedTimeout::huawei();
            black_box(sim.run(&mut policy).metrics.cold_starts);
        });
        println!("  -> {:.2}M invocations/s (collection on)\n", n / (s.median_ns / 1e9) / 1e6);
        report.add(s);
    }

    println!("== per-invocation pieces ==\n");
    // State encoding.
    let prof = trace.functions[0].clone();
    let ctx = lace_rl::policy::DecisionContext {
        t: 100.0,
        func: &prof,
        ci: 400.0,
        reuse_probs: [0.1, 0.3, 0.5, 0.7, 0.9],
        lambda_carbon: 0.5,
        idle_power_w: 1.2,
        next_arrival_gap: None,
    };
    report.add(bench("encoder/encode", || {
        black_box(lace_rl::rl::encoder::encode(black_box(&ctx)));
    }));

    // Reuse-window probability evaluation (W=64, the hot default).
    let mut w = ReuseWindow::new(64);
    for i in 0..64 {
        w.push((i as f64 * 1.7) % 90.0);
    }
    report.add(bench("reuse_window/probs(W=64)", || {
        black_box(w.probs());
    }));

    // CI integration across an hour boundary — O(1) prefix-sum path.
    let ct = CarbonTrace::new("b", 3600.0, (0..48).map(|i| 300.0 + i as f64).collect());
    report.add(bench("carbon/integrate(90min)", || {
        black_box(ct.integrate(black_box(1800.0), black_box(7200.0)));
    }));
    // The same integral over a week-long span: O(1) means span length must
    // not matter (the old step loop walked ~170 steps here).
    report.add(bench("carbon/integrate(7days)", || {
        black_box(ct.integrate(black_box(1800.0), black_box(604_800.0)));
    }));

    report.write("BENCH_sim.json")?;
    println!("\nwrote BENCH_sim.json");
    Ok(())
}
