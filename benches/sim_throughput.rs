//! Simulator/coordinator hot-path throughput: invocations simulated per
//! second per policy, plus microbenchmarks of the per-invocation pieces
//! (state encode, reuse-window probs, CI integration).
//!
//! This is the L3 perf-pass measurement target (DESIGN.md §8): ≥1M
//! simulated invocations/s with a trivial policy; the native-DQN run shows
//! the policy overhead on top.

use lace_rl::carbon::intensity::CarbonTrace;
use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::energy::model::EnergyModel;
use lace_rl::experiments::workload;
use lace_rl::policy::{CarbonMin, FixedTimeout, KeepAlivePolicy};
use lace_rl::simulator::engine::{SimConfig, Simulator};
use lace_rl::simulator::reuse::ReuseWindow;
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::util::bench::{bench, bench_once, black_box};

fn main() -> anyhow::Result<()> {
    println!("== simulator throughput ==\n");
    let trace = TraceGenerator::new(SynthConfig {
        n_functions: 200,
        duration_s: 7200.0,
        target_invocations: 200_000,
        seed: 7,
        ..SynthConfig::default()
    })
    .generate();
    let n = trace.len() as f64;
    println!("workload: {} invocations\n", trace.len());
    let ci = synth_region(Region::SolarHeavy, 1, 7);
    let energy = EnergyModel::default();

    let mut run_policy = |label: &str, policy: &mut dyn KeepAlivePolicy| {
        let sim = Simulator::new(&trace, &ci, energy.clone(), SimConfig::default());
        let s = bench_once(label, 5, || {
            black_box(sim.run(policy).metrics.cold_starts);
        });
        println!(
            "  -> {:.2}M invocations/s\n",
            n / (s.median_ns / 1e9) / 1e6
        );
    };

    run_policy("sim/fixed-60s (full run)", &mut FixedTimeout::huawei());
    run_policy("sim/carbon-min (full run)", &mut CarbonMin);
    let mut lace = workload::lace_rl_policy()?;
    run_policy("sim/lace-rl-native (full run)", &mut lace);

    println!("== per-invocation pieces ==\n");
    // State encoding.
    let prof = trace.functions[0].clone();
    let ctx = lace_rl::policy::DecisionContext {
        t: 100.0,
        func: &prof,
        ci: 400.0,
        reuse_probs: [0.1, 0.3, 0.5, 0.7, 0.9],
        lambda_carbon: 0.5,
        idle_power_w: 1.2,
        next_arrival_gap: None,
    };
    bench("encoder/encode", || {
        black_box(lace_rl::rl::encoder::encode(black_box(&ctx)));
    });

    // Reuse-window probability evaluation (W=64, the hot default).
    let mut w = ReuseWindow::new(64);
    for i in 0..64 {
        w.push((i as f64 * 1.7) % 90.0);
    }
    bench("reuse_window/probs(W=64)", || {
        black_box(w.probs());
    });

    // CI integration across an hour boundary.
    let ct = CarbonTrace::new("b", 3600.0, (0..48).map(|i| 300.0 + i as f64).collect());
    bench("carbon/integrate(90min)", || {
        black_box(ct.integrate(black_box(1800.0), black_box(7200.0)));
    });

    Ok(())
}
