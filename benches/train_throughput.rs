//! DQN gradient-step throughput: the pure-Rust `rl::native_train` batched
//! step vs the AOT PJRT `dqn_train_step` executable, in steps/sec on
//! identical replay minibatches (batch 64, dims 10-64-64-5).
//!
//! The native backend always runs; the PJRT rows are skipped when no
//! artifact set is built. When both run, the bench first **gates on
//! agreement**: 100 shared minibatches through both backends must keep
//! params and loss within 1e-5, else the process exits nonzero — a perf
//! number for a step that computes something different is meaningless.
//!
//! Writes `BENCH_train.json` (median ns + steps/s per label) so
//! `scripts/bench_smoke.sh` can track the training-path perf trajectory
//! across PRs. Pass `--smoke` for a shrunken workload (CI-scale).

use lace_rl::rl::backend::TrainBackend;
use lace_rl::rl::native_train::NativeBackend;
use lace_rl::rl::qnet::QNetParams;
use lace_rl::rl::replay::SampleBatch;
use lace_rl::rl::trainer::default_dims;
use lace_rl::runtime::backend::PjrtBackend;
use lace_rl::runtime::{artifacts, ArtifactSet, PjrtRuntime, TrainStep};
use lace_rl::util::bench::{bench_once, black_box, Report};
use lace_rl::util::rng::Rng;

fn synthetic_batch(rng: &mut Rng, batch: usize, n_actions: usize) -> SampleBatch {
    let mut sb = SampleBatch::new(batch);
    for x in sb.states.iter_mut() {
        *x = rng.f64() as f32;
    }
    for x in sb.next_states.iter_mut() {
        *x = rng.f64() as f32;
    }
    for a in sb.actions.iter_mut() {
        *a = rng.index(n_actions) as i32;
    }
    for r in sb.rewards.iter_mut() {
        *r = -(rng.f64() as f32);
    }
    for d in sb.dones.iter_mut() {
        *d = if rng.chance(0.2) { 1.0 } else { 0.0 };
    }
    sb
}

/// Time `chunk` gradient steps per sample on `backend`; returns steps/sec
/// from the median sample.
fn bench_backend(
    report: &mut Report,
    label: &str,
    backend: &mut dyn TrainBackend,
    batches: &[SampleBatch],
    chunk: usize,
    samples: usize,
) -> f64 {
    let mut t: u64 = 0;
    let s = bench_once(label, samples, || {
        for _ in 0..chunk {
            t += 1;
            let sb = &batches[t as usize % batches.len()];
            black_box(backend.step(t, sb).expect("gradient step"));
            if t % 500 == 0 {
                backend.sync_target();
            }
        }
    });
    let steps_per_s = chunk as f64 / (s.median_ns / 1e9);
    println!("  -> {steps_per_s:.0} steps/s\n");
    report.add(s);
    steps_per_s
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!("== train-step throughput{} ==\n", if smoke { " (smoke)" } else { "" });

    let dims = default_dims();
    let batch = 64;
    let init = QNetParams::he_uniform(dims, 3);
    let mut rng = Rng::new(9);
    let batches: Vec<SampleBatch> =
        (0..16).map(|_| synthetic_batch(&mut rng, batch, dims.3)).collect();

    let (chunk, samples) = if smoke { (100, 3) } else { (1000, 5) };
    let mut report = Report::new();

    // PJRT side is optional: artifact-less machines still get native rows.
    let dir = artifacts::default_dir();
    let pjrt = if std::path::Path::new(&dir).join("manifest.json").exists() {
        let art = ArtifactSet::open(&dir)?;
        anyhow::ensure!(
            art.manifest.dims() == dims && art.manifest.train_batch == batch,
            "artifact manifest disagrees with bench dims/batch"
        );
        let rt = PjrtRuntime::cpu()?;
        Some((art, rt))
    } else {
        println!("(no artifacts at {dir}; benching native backend only)\n");
        None
    };
    // Executables are cheap to reload; build one per use site rather than
    // threading a shared handle through ownership-taking constructors.
    let load_step = |art: &ArtifactSet, rt: &PjrtRuntime| -> anyhow::Result<TrainStep> {
        let exe = rt.load_hlo_text(art.train_step_path().to_str().unwrap())?;
        Ok(TrainStep::new(exe, batch, dims))
    };

    // --- Agreement gate: a wrong fast step must not produce a bench row.
    if let Some((ref art, ref rt)) = pjrt {
        let mut a = PjrtBackend::new(load_step(art, rt)?, init.clone());
        let mut b = NativeBackend::new(init.clone(), batch);
        let mut worst = 0.0f32;
        for t in 1..=100u64 {
            let sb = &batches[t as usize % batches.len()];
            let la = a.step(t, sb)?;
            let lb = b.step(t, sb)?;
            worst = worst.max((la - lb).abs());
            worst = worst.max(a.params().max_abs_diff(b.params()));
            if t % 25 == 0 {
                a.sync_target();
                b.sync_target();
            }
        }
        if worst > 1e-5 {
            eprintln!("AGREEMENT GATE FAILED: native vs PJRT max |Δ| = {worst:e} > 1e-5");
            std::process::exit(1);
        }
        println!("agreement gate: native vs PJRT max |Δ| = {worst:e} over 100 steps  OK\n");
    }

    // --- Throughput.
    let mut native = NativeBackend::new(init.clone(), batch);
    let native_sps =
        bench_backend(&mut report, "train/step-native", &mut native, &batches, chunk, samples);

    if let Some((ref art, ref rt)) = pjrt {
        let mut backend = PjrtBackend::new(load_step(art, rt)?, init);
        let pjrt_sps =
            bench_backend(&mut report, "train/step-pjrt", &mut backend, &batches, chunk, samples);
        println!("native/pjrt speedup: {:.2}x\n", native_sps / pjrt_sps.max(1e-9));
    }

    report.write("BENCH_train.json")?;
    println!("wrote BENCH_train.json");
    Ok(())
}
