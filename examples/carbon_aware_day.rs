//! Carbon-aware day: replay one day against three grid archetypes and show
//! how LACE-RL shifts its keep-alive mix with the hourly carbon intensity
//! (the Fig. 10b interpretability story as a runnable scenario).
//!
//! ```bash
//! cargo run --release --example carbon_aware_day
//! ```

use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::energy::model::EnergyModel;
use lace_rl::experiments::workload;
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::KEEP_ALIVE_ACTIONS;

fn main() -> anyhow::Result<()> {
    let trace = TraceGenerator::new(SynthConfig {
        n_functions: 100,
        duration_s: 86_400.0,
        target_invocations: 150_000,
        seed: 11,
        ..SynthConfig::default()
    })
    .generate();
    let energy = EnergyModel::default();
    println!(
        "one-day workload: {} invocations / {} functions\n",
        trace.len(),
        trace.functions.len()
    );

    for region in Region::ALL {
        let ci = synth_region(region, 1, 11);
        let mut lace = workload::lace_rl_policy()?.recording();
        let m = workload::evaluate(&trace, &ci, &energy, &mut lace, 0.5, false);

        // Hourly mix of short (1s) vs long (60s) keep-alives.
        let mut per_hour = vec![[0u64; 5]; 24];
        for d in &lace.decisions {
            per_hour[((d.t / 3600.0) as usize) % 24][d.action] += 1;
        }
        println!("=== {} ===", region.name());
        println!("{}", m.summary_row("lace-rl"));
        println!("  hour  CI(g/kWh)  keep-alive mix (1s … 60s)");
        for (hour, counts) in per_hour.iter().enumerate().step_by(3) {
            let total: u64 = counts.iter().sum();
            if total == 0 {
                continue;
            }
            let bars: Vec<String> = (0..KEEP_ALIVE_ACTIONS.len())
                .map(|a| format!("{:>4.0}%", 100.0 * counts[a] as f64 / total as f64))
                .collect();
            println!("  {hour:>4}  {:>9.0}  {}", ci.values[hour], bars.join(" "));
        }
        println!();
    }
    println!("expected shape: greener hours (low CI) → more long keep-alives;");
    println!("dirty hours (high CI) → the mix shifts toward 1 s (paper Fig. 10b).");
    Ok(())
}
