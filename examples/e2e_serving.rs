//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. Opens the AOT artifacts (L1 Pallas kernel + L2 jax graphs, lowered to
//!    HLO text) and verifies the PJRT executables against the native path.
//! 2. Trains the DQN **in Rust** for a few episodes by driving the AOT
//!    `dqn_train_step` via PJRT, logging the loss curve.
//! 3. Serves the held-out workload through the threaded online coordinator
//!    (driver → router → policy) with the trained network, reporting
//!    latency, throughput, and per-decision overhead (§IV-E).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_serving
//! ```
//! Recorded in EXPERIMENTS.md §End-to-end.

use lace_rl::coordinator::driver::Pace;
use lace_rl::coordinator::{CoordinatorServer, RouterConfig};
use lace_rl::experiments::workload;
use lace_rl::policy::lace_rl::{LaceRlPolicy, PjrtQ};
use lace_rl::policy::native_mlp::NativeMlp;
use lace_rl::policy::FixedTimeout;
use lace_rl::rl::trainer::{train, TrainerConfig};
use lace_rl::runtime::{artifacts, ArtifactSet, PjrtRuntime, QNetInfer};
use lace_rl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let seed = args.u64_or("seed", 7);
    let episodes = args.usize_or("episodes", 8);
    let quick = true; // e2e example always runs the CI-sized workload

    // ---- Layer check: artifacts + PJRT vs native agreement ----
    let artifacts = ArtifactSet::open(&artifacts::default_dir())?;
    let runtime = PjrtRuntime::cpu()?;
    println!(
        "[1/3] artifacts: platform={} dims={:?}",
        runtime.platform(),
        artifacts.manifest.dims()
    );
    let params = artifacts.init_params()?;
    let infer = QNetInfer::new(
        runtime.load_hlo_text(artifacts.infer_path(1).to_str().unwrap())?,
        1,
        artifacts.manifest.dims(),
    );
    let state: Vec<f32> = (0..10).map(|i| 0.05 * i as f32).collect();
    let q_pjrt = infer.q_values(&params, &state)?;
    let q_native = NativeMlp::new(params.clone()).forward(&state).to_vec();
    let diff = q_pjrt
        .iter()
        .zip(&q_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("      pallas-PJRT vs native max|Δq| = {diff:.2e}");
    anyhow::ensure!(diff < 1e-4, "layer disagreement");

    // ---- Train via the AOT train step ----
    let w = workload::build(seed, quick);
    println!(
        "[2/3] training {} episodes on {} invocations (AOT train step via PJRT)…",
        episodes,
        w.train.len()
    );
    let t0 = std::time::Instant::now();
    let report = train(
        &artifacts,
        &runtime,
        &w.train,
        &w.ci,
        &w.energy,
        &TrainerConfig {
            episodes,
            steps_per_episode: 400,
            verbose: false,
            seed,
            ..TrainerConfig::default()
        },
    )?;
    for e in report.episodes.iter().step_by(2.max(episodes / 4)) {
        println!(
            "      ep {:>2}  ε={:.2}  λ={:.1}  loss={:.5}  reward={:.1}",
            e.episode, e.epsilon, e.lambda, e.mean_loss, e.episode_reward
        );
    }
    println!(
        "      {} gradient steps in {:.1}s",
        report.total_steps,
        t0.elapsed().as_secs_f64()
    );

    // ---- Serve the held-out workload online ----
    println!("[3/3] serving the General test split through the coordinator…");
    let policy = LaceRlPolicy::new(NativeMlp::new(report.params.clone()));
    let (serve_report, _) = CoordinatorServer::run(
        &w.general,
        policy,
        w.ci.clone(),
        w.energy.clone(),
        RouterConfig::default(),
        Pace::MaxSpeed,
        1024,
    )?;
    serve_report.print("lace-rl");

    // Static baseline for contrast.
    let (huawei_report, _) = CoordinatorServer::run(
        &w.general,
        FixedTimeout::huawei(),
        w.ci.clone(),
        w.energy.clone(),
        RouterConfig::default(),
        Pace::MaxSpeed,
        1024,
    )?;
    huawei_report.print("huawei-60s");

    // The canonical AOT decision path: serve a slice with the PJRT-backed
    // Q-function (per-decision dispatch through XLA). PjRtClient is not
    // Send (Rc internally), so this router runs synchronously on the main
    // thread — same code path, no driver thread.
    let slice: Vec<lace_rl::coordinator::InvocationRequest> = w
        .general
        .invocations
        .iter()
        .take(2_000)
        .enumerate()
        .map(|(id, inv)| lace_rl::coordinator::InvocationRequest {
            id: id as u64,
            t: inv.t,
            func: inv.func,
            exec_s: inv.exec_s,
        })
        .collect();
    let pjrt_q = PjrtQ::new(
        QNetInfer::new(
            runtime.load_hlo_text(artifacts.infer_path(1).to_str().unwrap())?,
            1,
            artifacts.manifest.dims(),
        ),
        report.params.clone(),
    );
    let mut pjrt_router = lace_rl::coordinator::Router::new(
        w.general.functions.clone(),
        LaceRlPolicy::new(pjrt_q),
        w.ci.clone(),
        w.energy.clone(),
        RouterConfig::default(),
    );
    for req in &slice {
        pjrt_router.handle(req);
    }
    let pjrt_mean_us = pjrt_router.metrics.decision_ns.mean() / 1_000.0;
    println!(
        "[serve:lace-rl-pjrt] requests={} cold={} decision(mean)={:.1}µs (AOT Pallas path)",
        pjrt_router.metrics.requests, pjrt_router.metrics.cold_starts, pjrt_mean_us
    );

    println!(
        "\ne2e OK: cold starts {} (lace-rl) vs {} (huawei-60s); decision {:.1}µs native vs {:.1}µs pjrt",
        serve_report.cold_starts,
        huawei_report.cold_starts,
        serve_report.mean_decision_us,
        pjrt_mean_us
    );
    Ok(())
}
