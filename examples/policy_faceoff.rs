//! Policy face-off: every keep-alive policy (plus the clairvoyant Oracle)
//! on the General evaluation workload — the Fig. 5/7 comparison as a
//! single runnable binary.
//!
//! ```bash
//! cargo run --release --example policy_faceoff [-- --seed 7 --quick]
//! ```

use lace_rl::experiments::workload;
use lace_rl::metrics::Comparison;
use lace_rl::policy::dpso::DpsoConfig;
use lace_rl::policy::{CarbonMin, Dpso, FixedTimeout, LatencyMin, Oracle};
use lace_rl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let seed = args.u64_or("seed", 7);
    let quick = args.flag("quick") || std::env::var("LACE_QUICK").is_ok();
    let lambda = args.f64_or("lambda", 0.5);

    let w = workload::build(seed, quick);
    println!(
        "General workload: {} invocations / {} functions  (λ_carbon = {lambda})",
        w.general.len(),
        w.general.functions.len()
    );

    let mut cmp = Comparison::new("faceoff");
    let mut latency_min = LatencyMin;
    cmp.add("latency-min", workload::evaluate(&w.general, &w.ci, &w.energy, &mut latency_min, lambda, false));
    let mut carbon_min = CarbonMin;
    cmp.add("carbon-min", workload::evaluate(&w.general, &w.ci, &w.energy, &mut carbon_min, lambda, false));
    let mut huawei = FixedTimeout::huawei();
    cmp.add("huawei-60s", workload::evaluate(&w.general, &w.ci, &w.energy, &mut huawei, lambda, false));
    let mut dpso = Dpso::new(DpsoConfig::default());
    cmp.add("dpso-ecolife", workload::evaluate(&w.general, &w.ci, &w.energy, &mut dpso, lambda, false));
    let mut lace = workload::lace_rl_policy()?;
    cmp.add("lace-rl", workload::evaluate(&w.general, &w.ci, &w.energy, &mut lace, lambda, false));
    let mut oracle = Oracle;
    cmp.add("oracle", workload::evaluate(&w.general, &w.ci, &w.energy, &mut oracle, lambda, true));

    println!("\n{}", cmp.table());
    println!("normalized trade-off (ideal = bottom-left, 1.00×/1.00×):");
    for (name, cold, carbon) in cmp.tradeoff_coordinates() {
        println!("  {name:<16} cold ×{cold:<8.2} keep-alive carbon ×{carbon:.2}");
    }
    println!("\nbest LCP: {:?}   best IRI: {:?}", cmp.best_lcp(), cmp.best_iri());
    Ok(())
}
