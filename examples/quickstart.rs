//! Quickstart: generate a small synthetic workload, run LACE-RL against
//! Huawei's static 60 s keep-alive, and print the headline metrics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Uses trained weights when `artifacts/trained_weights.bin` exists (run
//! `cargo run --release -- train` first for the full effect); falls back to
//! the deterministic init weights otherwise.

use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::energy::model::EnergyModel;
use lace_rl::experiments::workload;
use lace_rl::policy::FixedTimeout;
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};

fn main() -> anyhow::Result<()> {
    // 1. A small Huawei-like workload: 60 functions, 2 hours.
    let trace = TraceGenerator::new(SynthConfig {
        n_functions: 60,
        duration_s: 7_200.0,
        target_invocations: 50_000,
        seed: 7,
        ..SynthConfig::default()
    })
    .generate();
    println!(
        "workload: {} invocations / {} functions / {:.1}h",
        trace.len(),
        trace.functions.len(),
        trace.duration_s() / 3600.0
    );

    // 2. A solar-heavy grid (duck-curve carbon intensity).
    let ci = synth_region(Region::SolarHeavy, 1, 7);
    let energy = EnergyModel::default();

    // 3. Compare the learned policy against the static production default.
    let mut lace = workload::lace_rl_policy()?;
    let lace_m = workload::evaluate(&trace, &ci, &energy, &mut lace, 0.5, false);
    let mut huawei = FixedTimeout::huawei();
    let huawei_m = workload::evaluate(&trace, &ci, &energy, &mut huawei, 0.5, false);

    println!("\n{}", huawei_m.summary_row("huawei-60s"));
    println!("{}", lace_m.summary_row("lace-rl"));
    println!(
        "\nLACE-RL vs static: {:+.1}% cold starts, {:+.1}% keep-alive carbon, {:+.1}% LCP",
        pct(lace_m.cold_starts as f64, huawei_m.cold_starts as f64),
        pct(lace_m.keepalive_carbon_g, huawei_m.keepalive_carbon_g),
        pct(lace_m.lcp(), huawei_m.lcp()),
    );
    Ok(())
}

fn pct(new: f64, old: f64) -> f64 {
    100.0 * (new - old) / old.max(1e-12)
}
