"""AOT pipeline: lower the L2 graphs to HLO *text* artifacts for Rust/PJRT.

Run via ``make artifacts`` (no-op when inputs are unchanged).  Emits:

  artifacts/dqn_infer_b1.hlo.txt      Pallas fused-MLP inference, batch 1
  artifacts/dqn_infer_b256.hlo.txt    Pallas fused-MLP inference, batch 256
  artifacts/dqn_infer_jnp_b1.hlo.txt  pure-jnp inference (Pallas ablation)
  artifacts/dqn_train_step.hlo.txt    full DQN + Adam train step, batch 64
  artifacts/init_weights.bin          deterministic He-init parameters
  artifacts/manifest.json             dims / action set / hyperparameters

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

WEIGHTS_MAGIC = b"LACEW001"


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via the stablehlo -> XlaComputation path."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs():
    return [_spec(model.PARAM_SHAPES[k]) for k in model.PARAM_KEYS]


def lower_infer(batch: int, use_pallas: bool = True) -> str:
    fn = model.dqn_infer if use_pallas else model.dqn_infer_jnp
    specs = _param_specs() + [_spec((batch, model.STATE_DIM))]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_train_step(batch: int) -> str:
    specs = (
        _param_specs() * 4                    # params, target, m, v
        + [_spec(())]                         # step t
        + [
            _spec((batch, model.STATE_DIM)),  # states
            _spec((batch,), jnp.int32),       # actions
            _spec((batch,)),                  # rewards
            _spec((batch, model.STATE_DIM)),  # next_states
            _spec((batch,)),                  # dones
        ]
    )
    return to_hlo_text(jax.jit(model.dqn_train_step).lower(*specs))


def write_weights(path: str, params) -> None:
    """Serialize a name->f32 tensor dict to the LACEW001 binary format.

    Layout (little-endian):
      magic[8] | u32 n | n x ( u32 name_len | name | u32 ndim | u32 dims[] |
      f32 data[] )

    Mirrored by rust/src/rl/weights.rs; change in lockstep.
    """
    import numpy as np

    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(struct.pack("<I", len(model.PARAM_KEYS)))
        for name in model.PARAM_KEYS:
            arr = np.asarray(params[name], dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes(order="C"))


def build(outdir: str, seed: int = 0) -> None:
    os.makedirs(outdir, exist_ok=True)

    def emit(name: str, text: str) -> None:
        path = os.path.join(outdir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {path} ({len(text)} chars)")

    print("[aot] lowering inference graphs (Pallas fused MLP)")
    emit("dqn_infer_b1.hlo.txt", lower_infer(1, use_pallas=True))
    emit("dqn_infer_b256.hlo.txt", lower_infer(256, use_pallas=True))
    print("[aot] lowering pure-jnp inference ablation")
    emit("dqn_infer_jnp_b1.hlo.txt", lower_infer(1, use_pallas=False))
    print("[aot] lowering train step (jnp fwd + Pallas td_target)")
    emit("dqn_train_step.hlo.txt", lower_train_step(model.TRAIN_BATCH))

    print("[aot] writing deterministic init weights")
    write_weights(os.path.join(outdir, "init_weights.bin"), model.init_params(seed))

    manifest = {
        "state_dim": model.STATE_DIM,
        "hidden": [model.HIDDEN1, model.HIDDEN2],
        "n_actions": model.N_ACTIONS,
        "actions_sec": [1.0, 5.0, 10.0, 30.0, 60.0],
        "train_batch": model.TRAIN_BATCH,
        "gamma": model.GAMMA,
        "lr": model.LR,
        "adam": [model.ADAM_B1, model.ADAM_B2, model.ADAM_EPS],
        "huber_delta": model.HUBER_DELTA,
        "param_keys": list(model.PARAM_KEYS),
        "infer_batches": [1, 256],
        "seed": seed,
    }
    mpath = os.path.join(outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {mpath}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    build(args.out, args.seed)


if __name__ == "__main__":
    main()
