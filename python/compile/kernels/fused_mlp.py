"""L1 Pallas kernel: fused 3-layer MLP forward (the DQN Q-network).

The paper's decision hot-spot is per-invocation Q-network inference
(Sec. IV-E: ~15 us / invocation).  This kernel fuses the whole forward pass
-- two hidden layers with ReLU plus the output head -- into a single Pallas
call so that on a real TPU the weights (~47 KB fp32) are staged into VMEM
once per grid step and every matmul feeds the MXU without round-tripping
activations through HBM.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * grid tiles the batch dimension (block = ``block_b`` rows); weights use a
    constant index_map so every grid step sees the full parameter set
    (one HBM->VMEM transfer amortized across the batch),
  * each (block_b x h1) @ (h1 x h2) product is MXU-shaped; dims are chosen
    as multiples of 8 lanes where the model allows,
  * VMEM footprint per step is ~0.3 MB << 16 MB, leaving room for
    double-buffering of the batch blocks.

``interpret=True`` is mandatory in this environment: real-TPU lowering emits
a Mosaic custom-call the CPU PJRT plugin cannot execute.  Correctness is
asserted against ``ref.mlp_forward`` in python/tests/.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    """Fused forward for one batch block.

    All refs live in VMEM for the duration of the grid step.  The whole
    chain is computed without writing intermediates back to HBM.
    """
    x = x_ref[...]
    h = jnp.maximum(x @ w1_ref[...] + b1_ref[...], 0.0)
    h = jnp.maximum(h @ w2_ref[...] + b2_ref[...], 0.0)
    o_ref[...] = h @ w3_ref[...] + b3_ref[...]


def fused_mlp(x, w1, b1, w2, b2, w3, b3, *, block_b: int | None = None):
    """Fused 3-layer MLP forward as a single Pallas call.

    Args:
      x: f32[B, d_in] batch of encoded states.
      w1..b3: MLP parameters (see ref.mlp_forward for shapes).
      block_b: batch tile size; must divide B.  Defaults to min(B, 128) --
        128 rows matches the MXU systolic height.

    Returns:
      f32[B, d_out] Q-values.
    """
    batch, d_in = x.shape
    h1 = w1.shape[1]
    h2 = w2.shape[1]
    d_out = w3.shape[1]
    if block_b is None:
        block_b = min(batch, 128)
    if batch % block_b != 0:
        raise ValueError(f"block_b={block_b} must divide batch={batch}")
    grid = (batch // block_b,)

    # Weights: constant index_map -> full tensor resident every grid step.
    def whole(*shape):
        ndim = len(shape)
        return pl.BlockSpec(shape, lambda i, _n=ndim: (0,) * _n)

    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i: (i, 0)),
            whole(d_in, h1),
            whole(h1),
            whole(h1, h2),
            whole(h2),
            whole(h2, d_out),
            whole(d_out),
        ],
        out_specs=pl.BlockSpec((block_b, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d_out), x.dtype),
        interpret=True,  # CPU-PJRT requirement; see module docstring.
    )(x, w1, b1, w2, b2, w3, b3)


def fused_mlp_params(x, params, *, block_b: int | None = None):
    """Convenience wrapper taking the params dict used by L2/model.py."""
    return fused_mlp(
        x,
        params["w1"],
        params["b1"],
        params["w2"],
        params["b2"],
        params["w3"],
        params["b3"],
        block_b=block_b,
    )
