"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground-truth implementations the Pallas kernels in
``fused_mlp.py`` and ``td_target.py`` are validated against (pytest +
hypothesis in ``python/tests/``).  They are also used directly inside the
differentiable branch of the DQN train step (L2), where autodiff must flow
through the forward pass.
"""

from __future__ import annotations

import jax.numpy as jnp


def mlp_forward(x, params):
    """3-layer MLP forward: Q(s) for a batch of states.

    Args:
      x: f32[B, d_in] batch of encoded states.
      params: dict with keys w1 [d_in,h1], b1 [h1], w2 [h1,h2], b2 [h2],
        w3 [h2,d_out], b3 [d_out].

    Returns:
      f32[B, d_out] Q-values, one column per keep-alive action.
    """
    h = jnp.maximum(x @ params["w1"] + params["b1"], 0.0)
    h = jnp.maximum(h @ params["w2"] + params["b2"], 0.0)
    return h @ params["w3"] + params["b3"]


def td_target(q_next, rewards, dones, gamma):
    """Bellman target: r + gamma * (1 - done) * max_a' Q'(s', a').

    Args:
      q_next: f32[B, A] target-network Q-values at next states.
      rewards: f32[B].
      dones: f32[B] in {0, 1}; 1 marks an episode-terminal transition.
      gamma: python float discount factor.

    Returns:
      f32[B] TD targets.
    """
    return rewards + gamma * (1.0 - dones) * jnp.max(q_next, axis=-1)
