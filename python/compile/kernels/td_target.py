"""L1 Pallas kernel: Bellman target computation for the DQN train step.

target[b] = r[b] + gamma * (1 - done[b]) * max_a' Q'(s'[b], a')

This lives on the *non-differentiated* branch of the train step (targets are
constants w.r.t. the online parameters), so a Pallas kernel is safe inside
the jax.grad'd loss: autodiff never has to traverse the pallas_call.

The reduction over the action axis is a lane-wise max on TPU (d_out = 5
actions pads to one 8-lane vector register); the kernel is purely
element-wise + reduce, VPU work with no MXU involvement.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _td_kernel(qn_ref, r_ref, done_ref, o_ref, *, gamma: float):
    qn = qn_ref[...]          # [block_b, A]
    r = r_ref[...]            # [block_b]
    done = done_ref[...]      # [block_b]
    o_ref[...] = r + gamma * (1.0 - done) * jnp.max(qn, axis=-1)


def td_target(q_next, rewards, dones, *, gamma: float, block_b: int | None = None):
    """Bellman targets as a Pallas call.

    Args:
      q_next: f32[B, A] target-network Q-values at next states.
      rewards: f32[B].
      dones: f32[B] in {0, 1}.
      gamma: discount factor (baked into the kernel as a compile-time const).
      block_b: batch tile; must divide B.  Defaults to B (single grid step --
        the tensor is tiny).

    Returns:
      f32[B] TD targets.
    """
    batch, n_actions = q_next.shape
    if block_b is None:
        block_b = batch
    if batch % block_b != 0:
        raise ValueError(f"block_b={block_b} must divide batch={batch}")
    grid = (batch // block_b,)

    import functools

    return pl.pallas_call(
        functools.partial(_td_kernel, gamma=gamma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n_actions), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), q_next.dtype),
        interpret=True,  # CPU-PJRT requirement.
    )(q_next, rewards, dones)
