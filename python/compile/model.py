"""L2: the LACE-RL DQN compute graph (forward + full train step) in JAX.

The paper (Sec. III-C) uses a lightweight fully-connected Q-network:
  input  : 10-dim state  [p_k1..p_k5, mem, cpu, L_cold, CI_t, lambda_carbon]
  hidden : 64 -> 64, ReLU
  output : 5 Q-values, one per keep-alive action {1, 5, 10, 30, 60} s

Everything here is build-time Python: ``aot.py`` lowers these functions once
to HLO text and the Rust coordinator (L3) drives the compiled executables via
PJRT.  Python never runs on the decision path.

Design split between the two L1 Pallas kernels:
  * inference graphs call the fused_mlp Pallas kernel (the hot path),
  * the train step computes the *online* forward with the pure-jnp reference
    (autodiff must flow through it) and the Bellman *targets* with the
    td_target Pallas kernel on the stop-gradient branch, where autodiff never
    looks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import fused_mlp as fused_mlp_k
from compile.kernels import ref
from compile.kernels import td_target as td_target_k

# ---------------------------------------------------------------------------
# Architecture constants — mirrored in rust/src/rl/qnet.rs and the artifact
# manifest; change in lockstep.
# ---------------------------------------------------------------------------
STATE_DIM = 10
HIDDEN1 = 64
HIDDEN2 = 64
N_ACTIONS = 5          # keep-alive set {1, 5, 10, 30, 60} s
TRAIN_BATCH = 64       # paper Sec. IV-A4
GAMMA = 0.99           # paper Sec. IV-A4
LR = 1e-3              # paper Sec. IV-A4
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
HUBER_DELTA = 1.0      # Huber TD loss for stability (standard DQN practice)

PARAM_KEYS = ("w1", "b1", "w2", "b2", "w3", "b3")
PARAM_SHAPES = {
    "w1": (STATE_DIM, HIDDEN1),
    "b1": (HIDDEN1,),
    "w2": (HIDDEN1, HIDDEN2),
    "b2": (HIDDEN2,),
    "w3": (HIDDEN2, N_ACTIONS),
    "b3": (N_ACTIONS,),
}


def init_params(seed: int = 0):
    """He-uniform initialization, deterministic in the seed.

    Runs once at artifact-build time; the resulting tensors are written to
    ``artifacts/init_weights.bin`` for the Rust trainer to load.
    """
    key = jax.random.PRNGKey(seed)
    params = {}
    for name in ("w1", "w2", "w3"):
        key, sub = jax.random.split(key)
        shape = PARAM_SHAPES[name]
        fan_in = shape[0]
        bound = (6.0 / fan_in) ** 0.5
        params[name] = jax.random.uniform(
            sub, shape, jnp.float32, minval=-bound, maxval=bound
        )
    for name in ("b1", "b2", "b3"):
        params[name] = jnp.zeros(PARAM_SHAPES[name], jnp.float32)
    return params


def _params_from_flat(flat):
    return dict(zip(PARAM_KEYS, flat))


def _flat_from_params(params):
    return tuple(params[k] for k in PARAM_KEYS)


# ---------------------------------------------------------------------------
# Inference graphs (AOT-lowered per batch size)
# ---------------------------------------------------------------------------


def dqn_infer(w1, b1, w2, b2, w3, b3, states):
    """Q-values for a batch of states via the fused Pallas MLP kernel.

    Returns a 1-tuple (rust unwraps with to_tuple1).
    """
    q = fused_mlp_k.fused_mlp(states, w1, b1, w2, b2, w3, b3)
    return (q,)


def dqn_infer_jnp(w1, b1, w2, b2, w3, b3, states):
    """Pure-jnp inference graph — the no-Pallas ablation artifact.

    Used by the perf pass to separate interpret-mode Pallas overhead from
    PJRT dispatch overhead (EXPERIMENTS.md §Perf).
    """
    q = ref.mlp_forward(states, _params_from_flat((w1, b1, w2, b2, w3, b3)))
    return (q,)


# ---------------------------------------------------------------------------
# Train step (AOT-lowered once at TRAIN_BATCH)
# ---------------------------------------------------------------------------


def _huber(err):
    """Element-wise Huber loss on TD error."""
    abs_err = jnp.abs(err)
    quad = jnp.minimum(abs_err, HUBER_DELTA)
    return 0.5 * quad * quad + HUBER_DELTA * (abs_err - quad)


def dqn_train_step(*args):
    """One DQN + Adam step as a pure function.

    Flat signature (AOT interchange; all f32 unless noted):
      args[0:6]    online params   (w1, b1, w2, b2, w3, b3)
      args[6:12]   target params   (same order)
      args[12:18]  Adam first moments m
      args[18:24]  Adam second moments v
      args[24]     step counter t (scalar f32; 1-based for bias correction)
      args[25]     states      [B, STATE_DIM]
      args[26]     actions     [B] i32 indices into the keep-alive set
      args[27]     rewards     [B]
      args[28]     next_states [B, STATE_DIM]
      args[29]     dones       [B] in {0, 1}

    Returns (tuple of 19):
      new params (6), new m (6), new v (6), loss scalar.
    """
    params = _params_from_flat(args[0:6])
    target_params = _params_from_flat(args[6:12])
    m = _params_from_flat(args[12:18])
    v = _params_from_flat(args[18:24])
    t = args[24]
    states, actions, rewards, next_states, dones = args[25:30]

    # --- Bellman targets: target net forward + Pallas td_target kernel.
    # Entirely constant w.r.t. `params`; wrapped in stop_gradient for clarity.
    q_next = ref.mlp_forward(next_states, target_params)
    targets = td_target_k.td_target(q_next, rewards, dones, gamma=GAMMA)
    targets = jax.lax.stop_gradient(targets)

    def loss_fn(p):
        q = ref.mlp_forward(states, p)  # differentiable branch: pure jnp
        batch = q.shape[0]
        q_sel = q[jnp.arange(batch), actions]
        return jnp.mean(_huber(q_sel - targets))

    loss, grads = jax.value_and_grad(loss_fn)(params)

    # --- Adam update with bias correction.
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_params, new_m, new_v = {}, {}, {}
    for k in PARAM_KEYS:
        g = grads[k]
        new_m[k] = ADAM_B1 * m[k] + (1.0 - ADAM_B1) * g
        new_v[k] = ADAM_B2 * v[k] + (1.0 - ADAM_B2) * g * g
        m_hat = new_m[k] / bc1
        v_hat = new_v[k] / bc2
        new_params[k] = params[k] - LR * m_hat / (jnp.sqrt(v_hat) + ADAM_EPS)

    return (
        *_flat_from_params(new_params),
        *_flat_from_params(new_m),
        *_flat_from_params(new_v),
        loss,
    )


def train_step_reference(params, target_params, m, v, t, batch):
    """Dict-based wrapper used by the python-side tests."""
    out = dqn_train_step(
        *_flat_from_params(params),
        *_flat_from_params(target_params),
        *_flat_from_params(m),
        *_flat_from_params(v),
        jnp.float32(t),
        batch["states"],
        batch["actions"],
        batch["rewards"],
        batch["next_states"],
        batch["dones"],
    )
    return (
        _params_from_flat(out[0:6]),
        _params_from_flat(out[6:12]),
        _params_from_flat(out[12:18]),
        out[18],
    )
