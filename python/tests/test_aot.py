"""AOT pipeline tests: lowering produces loadable HLO text + weight format.

These run the actual lowering path (slow-ish: pallas interpret lowering) and
validate the artifacts the Rust side depends on, without requiring the Rust
toolchain.
"""

from __future__ import annotations

import json
import os
import struct

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


class TestLowering:
    def test_infer_b1_hlo_text(self):
        text = aot.lower_infer(1, use_pallas=True)
        assert "HloModule" in text
        # 6 params + 1 state input
        assert text.count("parameter(") >= 7

    def test_infer_jnp_hlo_text(self):
        text = aot.lower_infer(1, use_pallas=False)
        assert "HloModule" in text
        # the jnp graph is dense dots, no control flow
        assert "dot(" in text or "dot " in text

    def test_train_step_hlo_text(self):
        text = aot.lower_train_step(model.TRAIN_BATCH)
        assert "HloModule" in text
        assert text.count("parameter(") >= 30

    def test_hlo_text_parseable_by_xla_client(self):
        """Round-trip: text -> XlaComputation via the local xla_client."""
        from jax._src.lib import xla_client as xc

        text = aot.lower_infer(1, use_pallas=False)
        # xla_client can re-parse its own HLO text
        mod = xc._xla.hlo_module_from_text(text)
        assert mod is not None


class TestWeightsFormat:
    def test_roundtrip_layout(self, tmp_path):
        path = str(tmp_path / "w.bin")
        params = model.init_params(42)
        aot.write_weights(path, params)
        with open(path, "rb") as f:
            data = f.read()
        assert data[:8] == aot.WEIGHTS_MAGIC
        (n,) = struct.unpack_from("<I", data, 8)
        assert n == len(model.PARAM_KEYS)
        off = 12
        seen = {}
        for _ in range(n):
            (name_len,) = struct.unpack_from("<I", data, off)
            off += 4
            name = data[off : off + name_len].decode()
            off += name_len
            (ndim,) = struct.unpack_from("<I", data, off)
            off += 4
            dims = struct.unpack_from(f"<{ndim}I", data, off)
            off += 4 * ndim
            count = int(np.prod(dims)) if ndim else 1
            arr = np.frombuffer(data, dtype="<f4", count=count, offset=off)
            off += 4 * count
            seen[name] = arr.reshape(dims)
        assert off == len(data)
        for k in model.PARAM_KEYS:
            np.testing.assert_array_equal(
                seen[k], np.asarray(params[k], dtype=np.float32)
            )

    def test_build_writes_manifest(self, tmp_path):
        # Full build is expensive; only check manifest content via build of
        # weights + manifest pieces. Use the real build when artifacts are
        # missing in CI (make artifacts covers it).
        manifest = {
            "state_dim": model.STATE_DIM,
            "n_actions": model.N_ACTIONS,
        }
        assert manifest["state_dim"] == 10
        assert manifest["n_actions"] == 5


@pytest.mark.skipif(
    not os.path.isdir(os.path.join(os.path.dirname(__file__), "../../artifacts")),
    reason="artifacts/ not built",
)
class TestBuiltArtifacts:
    """Validate the artifacts actually present on disk (after make artifacts)."""

    ART = os.path.normpath(os.path.join(os.path.dirname(__file__), "../../artifacts"))

    def test_all_files_present(self):
        expected = [
            "dqn_infer_b1.hlo.txt",
            "dqn_infer_b256.hlo.txt",
            "dqn_infer_jnp_b1.hlo.txt",
            "dqn_train_step.hlo.txt",
            "init_weights.bin",
            "manifest.json",
        ]
        for name in expected:
            assert os.path.isfile(os.path.join(self.ART, name)), name

    def test_manifest_consistent_with_model(self):
        with open(os.path.join(self.ART, "manifest.json")) as f:
            m = json.load(f)
        assert m["state_dim"] == model.STATE_DIM
        assert m["n_actions"] == model.N_ACTIONS
        assert m["hidden"] == [model.HIDDEN1, model.HIDDEN2]
        assert m["actions_sec"] == [1.0, 5.0, 10.0, 30.0, 60.0]
