"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core correctness signal for the compiled artifacts: if the
kernels match ref.py here, and the Rust integration test matches the PJRT
execution of the lowered HLO against the same oracle values, the whole AOT
chain is validated end to end.

hypothesis sweeps shapes (batch, hidden dims) and value ranges.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_mlp, ref, td_target


def _rand_params(rng, d_in, h1, h2, d_out, scale=1.0):
    return {
        "w1": jnp.asarray(rng.standard_normal((d_in, h1)), jnp.float32) * scale,
        "b1": jnp.asarray(rng.standard_normal(h1), jnp.float32) * scale,
        "w2": jnp.asarray(rng.standard_normal((h1, h2)), jnp.float32) * scale,
        "b2": jnp.asarray(rng.standard_normal(h2), jnp.float32) * scale,
        "w3": jnp.asarray(rng.standard_normal((h2, d_out)), jnp.float32) * scale,
        "b3": jnp.asarray(rng.standard_normal(d_out), jnp.float32) * scale,
    }


# ---------------------------------------------------------------------------
# fused_mlp
# ---------------------------------------------------------------------------


class TestFusedMlp:
    @pytest.mark.parametrize("batch", [1, 2, 64, 128, 256])
    def test_matches_ref_paper_dims(self, batch):
        """Paper architecture (10 -> 64 -> 64 -> 5) at every batch the AOT
        pipeline emits."""
        rng = np.random.default_rng(batch)
        params = _rand_params(rng, 10, 64, 64, 5, scale=0.3)
        x = jnp.asarray(rng.standard_normal((batch, 10)), jnp.float32)
        got = fused_mlp.fused_mlp_params(x, params)
        want = ref.mlp_forward(x, params)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_explicit_block_b(self):
        rng = np.random.default_rng(7)
        params = _rand_params(rng, 10, 64, 64, 5, scale=0.3)
        x = jnp.asarray(rng.standard_normal((64, 10)), jnp.float32)
        for block in (8, 16, 32, 64):
            got = fused_mlp.fused_mlp_params(x, params, block_b=block)
            want = ref.mlp_forward(x, params)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_block_must_divide_batch(self):
        rng = np.random.default_rng(1)
        params = _rand_params(rng, 10, 64, 64, 5)
        x = jnp.zeros((10, 10), jnp.float32)
        with pytest.raises(ValueError):
            fused_mlp.fused_mlp_params(x, params, block_b=3)

    def test_relu_actually_clips(self):
        """All-negative weights + zero bias -> output is b3 exactly."""
        d_in, h1, h2, d_out = 10, 64, 64, 5
        params = {
            "w1": -jnp.ones((d_in, h1), jnp.float32),
            "b1": jnp.zeros((h1,), jnp.float32),
            "w2": jnp.ones((h1, h2), jnp.float32),
            "b2": jnp.zeros((h2,), jnp.float32),
            "w3": jnp.ones((h2, d_out), jnp.float32),
            "b3": jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0], jnp.float32),
        }
        x = jnp.ones((4, d_in), jnp.float32)  # x @ w1 < 0 -> relu -> 0
        got = fused_mlp.fused_mlp_params(x, params)
        np.testing.assert_allclose(got, jnp.tile(params["b3"], (4, 1)))

    @settings(max_examples=25, deadline=None)
    @given(
        batch_pow=st.integers(0, 6),
        d_in=st.integers(1, 24),
        h1=st.integers(1, 96),
        h2=st.integers(1, 96),
        d_out=st.integers(1, 12),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, batch_pow, d_in, h1, h2, d_out, seed):
        """Kernel is shape-generic: sweep arbitrary layer dims."""
        batch = 2**batch_pow
        rng = np.random.default_rng(seed)
        params = _rand_params(rng, d_in, h1, h2, d_out, scale=0.2)
        x = jnp.asarray(rng.standard_normal((batch, d_in)), jnp.float32)
        got = fused_mlp.fused_mlp_params(x, params)
        want = ref.mlp_forward(x, params)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        scale=st.floats(1e-3, 1e3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_value_range(self, scale, seed):
        """Numerics hold across input magnitudes (f32 relative tolerance)."""
        rng = np.random.default_rng(seed)
        params = _rand_params(rng, 10, 64, 64, 5, scale=0.3)
        x = jnp.asarray(rng.standard_normal((8, 10)) * scale, jnp.float32)
        got = fused_mlp.fused_mlp_params(x, params)
        want = ref.mlp_forward(x, params)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale)


# ---------------------------------------------------------------------------
# td_target
# ---------------------------------------------------------------------------


class TestTdTarget:
    @pytest.mark.parametrize("batch", [1, 16, 64])
    @pytest.mark.parametrize("gamma", [0.0, 0.9, 0.99, 1.0])
    def test_matches_ref(self, batch, gamma):
        rng = np.random.default_rng(batch)
        qn = jnp.asarray(rng.standard_normal((batch, 5)), jnp.float32)
        r = jnp.asarray(rng.standard_normal(batch), jnp.float32)
        d = jnp.asarray(rng.integers(0, 2, batch), jnp.float32)
        got = td_target.td_target(qn, r, d, gamma=gamma)
        want = ref.td_target(qn, r, d, gamma)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_terminal_transitions_ignore_bootstrap(self):
        qn = jnp.full((4, 5), 100.0, jnp.float32)
        r = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
        d = jnp.ones((4,), jnp.float32)
        got = td_target.td_target(qn, r, d, gamma=0.99)
        np.testing.assert_allclose(got, r)

    def test_nonterminal_bootstraps_max(self):
        qn = jnp.asarray([[1.0, 5.0, 2.0, 0.0, -1.0]], jnp.float32)
        r = jnp.asarray([1.0], jnp.float32)
        d = jnp.zeros((1,), jnp.float32)
        got = td_target.td_target(qn, r, d, gamma=0.5)
        np.testing.assert_allclose(got, [1.0 + 0.5 * 5.0])

    @settings(max_examples=25, deadline=None)
    @given(
        batch=st.sampled_from([1, 2, 4, 8, 32, 64, 128]),
        n_actions=st.integers(1, 16),
        gamma=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, batch, n_actions, gamma, seed):
        rng = np.random.default_rng(seed)
        qn = jnp.asarray(rng.standard_normal((batch, n_actions)), jnp.float32)
        r = jnp.asarray(rng.standard_normal(batch), jnp.float32)
        d = jnp.asarray(rng.integers(0, 2, batch), jnp.float32)
        got = td_target.td_target(qn, r, d, gamma=float(gamma))
        want = ref.td_target(qn, r, d, float(gamma))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
