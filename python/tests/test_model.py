"""L2 correctness: DQN train step semantics (gradients, Adam, targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _zeros_like_params():
    return {k: jnp.zeros(model.PARAM_SHAPES[k], jnp.float32) for k in model.PARAM_KEYS}


def _rand_batch(rng, batch=model.TRAIN_BATCH):
    return {
        "states": jnp.asarray(rng.standard_normal((batch, model.STATE_DIM)), jnp.float32),
        "actions": jnp.asarray(rng.integers(0, model.N_ACTIONS, batch), jnp.int32),
        "rewards": jnp.asarray(rng.standard_normal(batch), jnp.float32),
        "next_states": jnp.asarray(
            rng.standard_normal((batch, model.STATE_DIM)), jnp.float32
        ),
        "dones": jnp.asarray(rng.integers(0, 2, batch), jnp.float32),
    }


class TestInit:
    def test_deterministic(self):
        a = model.init_params(0)
        b = model.init_params(0)
        for k in model.PARAM_KEYS:
            np.testing.assert_array_equal(a[k], b[k])

    def test_seed_changes_weights(self):
        a = model.init_params(0)
        b = model.init_params(1)
        assert not np.allclose(a["w1"], b["w1"])

    def test_shapes(self):
        p = model.init_params(0)
        for k, shape in model.PARAM_SHAPES.items():
            assert p[k].shape == shape

    def test_he_bound(self):
        p = model.init_params(0)
        bound = (6.0 / model.STATE_DIM) ** 0.5
        assert np.max(np.abs(p["w1"])) <= bound
        assert np.allclose(p["b1"], 0.0)


class TestInferGraphs:
    def test_pallas_and_jnp_agree(self):
        rng = np.random.default_rng(0)
        p = model.init_params(0)
        flat = tuple(p[k] for k in model.PARAM_KEYS)
        x = jnp.asarray(rng.standard_normal((1, model.STATE_DIM)), jnp.float32)
        (qa,) = model.dqn_infer(*flat, x)
        (qb,) = model.dqn_infer_jnp(*flat, x)
        np.testing.assert_allclose(qa, qb, rtol=1e-5, atol=1e-6)

    def test_batch256(self):
        rng = np.random.default_rng(1)
        p = model.init_params(0)
        flat = tuple(p[k] for k in model.PARAM_KEYS)
        x = jnp.asarray(rng.standard_normal((256, model.STATE_DIM)), jnp.float32)
        (q,) = model.dqn_infer(*flat, x)
        assert q.shape == (256, model.N_ACTIONS)
        np.testing.assert_allclose(q, ref.mlp_forward(x, p), rtol=1e-5, atol=1e-5)


class TestTrainStep:
    def test_loss_decreases_on_repeated_batch(self):
        """Sanity: Adam on a fixed batch must reduce the TD loss."""
        rng = np.random.default_rng(0)
        params = model.init_params(0)
        target = model.init_params(0)
        m = _zeros_like_params()
        v = _zeros_like_params()
        batch = _rand_batch(rng)
        losses = []
        for t in range(1, 60):
            params, m, v, loss = model.train_step_reference(
                params, target, m, v, float(t), batch
            )
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.5, losses[::10]

    def test_gradient_matches_manual(self):
        """value_and_grad inside the step equals jax.grad of the same loss."""
        rng = np.random.default_rng(3)
        params = model.init_params(0)
        target = model.init_params(1)
        batch = _rand_batch(rng)

        q_next = ref.mlp_forward(batch["next_states"], target)
        targets = ref.td_target(q_next, batch["rewards"], batch["dones"], model.GAMMA)

        def loss_fn(p):
            q = ref.mlp_forward(batch["states"], p)
            q_sel = q[jnp.arange(q.shape[0]), batch["actions"]]
            err = q_sel - targets
            a = jnp.abs(err)
            quad = jnp.minimum(a, model.HUBER_DELTA)
            return jnp.mean(0.5 * quad * quad + model.HUBER_DELTA * (a - quad))

        grads = jax.grad(loss_fn)(params)

        # One train step from zero moments with t=1: Adam's bias-corrected
        # first step is -lr * g / (|g| + eps) elementwise... verify the
        # update direction matches sign(-g) where |g| is non-negligible.
        m = _zeros_like_params()
        v = _zeros_like_params()
        new_params, _, _, _ = model.train_step_reference(
            params, target, m, v, 1.0, batch
        )
        for k in ("w1", "w3"):
            delta = np.asarray(new_params[k] - params[k])
            g = np.asarray(grads[k])
            mask = np.abs(g) > 1e-6
            assert np.all(np.sign(delta[mask]) == -np.sign(g[mask]))

    def test_targets_use_target_network(self):
        """Changing target params changes loss; changing them must not
        change the gradient path (online forward unchanged)."""
        rng = np.random.default_rng(4)
        params = model.init_params(0)
        m = _zeros_like_params()
        v = _zeros_like_params()
        batch = _rand_batch(rng)
        _, _, _, loss_a = model.train_step_reference(
            params, model.init_params(1), m, v, 1.0, batch
        )
        _, _, _, loss_b = model.train_step_reference(
            params, model.init_params(2), m, v, 1.0, batch
        )
        assert float(loss_a) != float(loss_b)

    def test_pure_function_no_state(self):
        """Same inputs -> identical outputs (required for AOT replay)."""
        rng = np.random.default_rng(5)
        params = model.init_params(0)
        target = model.init_params(1)
        m = _zeros_like_params()
        v = _zeros_like_params()
        batch = _rand_batch(rng)
        out1 = model.train_step_reference(params, target, m, v, 1.0, batch)
        out2 = model.train_step_reference(params, target, m, v, 1.0, batch)
        for k in model.PARAM_KEYS:
            np.testing.assert_array_equal(out1[0][k], out2[0][k])
        assert float(out1[3]) == float(out2[3])

    def test_huber_bounds_gradient(self):
        """With a huge TD error the Huber loss is linear: per-element grad
        of loss w.r.t. q_sel is bounded by delta/B."""
        params = model.init_params(0)
        batch = {
            "states": jnp.ones((model.TRAIN_BATCH, model.STATE_DIM), jnp.float32),
            "actions": jnp.zeros((model.TRAIN_BATCH,), jnp.int32),
            "rewards": jnp.full((model.TRAIN_BATCH,), 1e6, jnp.float32),
            "next_states": jnp.ones((model.TRAIN_BATCH, model.STATE_DIM), jnp.float32),
            "dones": jnp.ones((model.TRAIN_BATCH,), jnp.float32),
        }
        m = _zeros_like_params()
        v = _zeros_like_params()
        new_params, new_m, _, _ = model.train_step_reference(
            params, params, m, v, 1.0, batch
        )
        # First moment is (1-b1) * g; Huber keeps |g| finite.
        g_w3 = np.asarray(new_m["b3"]) / (1.0 - model.ADAM_B1)
        assert np.all(np.isfinite(g_w3))
        assert np.max(np.abs(g_w3)) <= model.HUBER_DELTA + 1e-6
