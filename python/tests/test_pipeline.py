"""Full AOT pipeline test: build() into a temp dir, validate every artifact.

Slow (lowers all graphs) but exercises exactly what `make artifacts` runs.
"""

from __future__ import annotations

import json
import os

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(out, seed=0)
    return out


class TestBuild:
    def test_all_artifacts_written(self, built):
        names = sorted(os.listdir(built))
        assert names == [
            "dqn_infer_b1.hlo.txt",
            "dqn_infer_b256.hlo.txt",
            "dqn_infer_jnp_b1.hlo.txt",
            "dqn_train_step.hlo.txt",
            "init_weights.bin",
            "manifest.json",
        ]

    def test_manifest_roundtrips(self, built):
        with open(os.path.join(built, "manifest.json")) as f:
            m = json.load(f)
        assert m["state_dim"] == model.STATE_DIM
        assert m["actions_sec"] == [1.0, 5.0, 10.0, 30.0, 60.0]
        assert m["param_keys"] == list(model.PARAM_KEYS)

    def test_hlo_files_are_parseable(self, built):
        from jax._src.lib import xla_client as xc

        for name in [
            "dqn_infer_b1.hlo.txt",
            "dqn_infer_b256.hlo.txt",
            "dqn_infer_jnp_b1.hlo.txt",
            "dqn_train_step.hlo.txt",
        ]:
            with open(os.path.join(built, name)) as f:
                text = f.read()
            mod = xc._xla.hlo_module_from_text(text)
            assert mod is not None, name

    def test_init_weights_match_seed(self, built):
        import struct

        params = model.init_params(0)
        with open(os.path.join(built, "init_weights.bin"), "rb") as f:
            data = f.read()
        # First tensor is w1 (shape [10, 64]); verify content equality.
        off = 8 + 4
        (nl,) = struct.unpack_from("<I", data, off)
        off += 4
        name = data[off : off + nl].decode()
        off += nl
        (nd,) = struct.unpack_from("<I", data, off)
        off += 4
        dims = struct.unpack_from(f"<{nd}I", data, off)
        off += 4 * nd
        assert name == "w1" and dims == (10, 64)
        w1 = np.frombuffer(data, "<f4", count=640, offset=off).reshape(10, 64)
        np.testing.assert_array_equal(w1, np.asarray(params["w1"], np.float32))

    def test_deterministic_rebuild(self, built, tmp_path):
        out2 = str(tmp_path / "again")
        aot.build(out2, seed=0)
        for name in ["init_weights.bin", "dqn_infer_jnp_b1.hlo.txt"]:
            with open(os.path.join(built, name), "rb") as a:
                da = a.read()
            with open(os.path.join(out2, name), "rb") as b:
                db = b.read()
            assert da == db, f"{name} not deterministic"


class TestExecuteLoweredGraphs:
    """Run the lowered graphs through jax itself as a cross-check of what
    the Rust PJRT client executes."""

    def test_infer_semantics_match_direct_call(self, built):
        import jax
        import jax.numpy as jnp

        from compile.kernels import ref

        params = model.init_params(0)
        flat = tuple(params[k] for k in model.PARAM_KEYS)
        x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 10)), jnp.float32)
        (q,) = jax.jit(model.dqn_infer)(*flat, x)
        want = ref.mlp_forward(x, params)
        np.testing.assert_allclose(q, want, rtol=1e-5, atol=1e-6)
