//! Carbon-intensity time series with step-wise (hourly) evaluation.

/// A carbon-intensity trace: values in gCO₂eq/kWh sampled every `step_s`
/// seconds starting at t=0. Lookups beyond the end wrap around (diurnal
/// profiles repeat), matching the paper's hourly sampling (§IV-A3).
#[derive(Debug, Clone)]
pub struct CarbonTrace {
    pub step_s: f64,
    pub values: Vec<f64>,
    pub region: String,
}

impl CarbonTrace {
    pub fn new(region: &str, step_s: f64, values: Vec<f64>) -> Self {
        assert!(step_s > 0.0 && !values.is_empty());
        CarbonTrace { step_s, values, region: region.to_string() }
    }

    /// Constant CI — the ablation baseline (no temporal signal).
    pub fn constant(ci: f64) -> Self {
        CarbonTrace::new("constant", 3600.0, vec![ci])
    }

    /// CI at time `t` (seconds from trace start). Piecewise constant per
    /// step; wraps past the end.
    #[inline]
    pub fn at(&self, t: f64) -> f64 {
        let idx = (t / self.step_s).floor() as i64;
        let n = self.values.len() as i64;
        let idx = ((idx % n) + n) % n; // euclidean wrap (handles t<0 too)
        self.values[idx as usize]
    }

    /// Integral of CI over [t0, t1] in (gCO₂eq/kWh)·s — used to carbon-weight
    /// idle energy that spans step boundaries.
    pub fn integrate(&self, t0: f64, t1: f64) -> f64 {
        if t1 <= t0 {
            return 0.0;
        }
        let mut acc = 0.0;
        let mut t = t0;
        while t < t1 {
            let step_end = ((t / self.step_s).floor() + 1.0) * self.step_s;
            let seg_end = step_end.min(t1);
            acc += self.at(t) * (seg_end - t);
            t = seg_end;
        }
        acc
    }

    /// Mean CI over [t0, t1].
    pub fn mean_over(&self, t0: f64, t1: f64) -> f64 {
        if t1 <= t0 {
            return self.at(t0);
        }
        self.integrate(t0, t1) / (t1 - t0)
    }

    pub fn duration_s(&self) -> f64 {
        self.step_s * self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_step() -> CarbonTrace {
        CarbonTrace::new("t", 10.0, vec![100.0, 300.0])
    }

    #[test]
    fn piecewise_constant_lookup() {
        let c = two_step();
        assert_eq!(c.at(0.0), 100.0);
        assert_eq!(c.at(9.999), 100.0);
        assert_eq!(c.at(10.0), 300.0);
    }

    #[test]
    fn wraps_around() {
        let c = two_step();
        assert_eq!(c.at(20.0), 100.0);
        assert_eq!(c.at(35.0), 300.0);
        assert_eq!(c.at(-5.0), 300.0); // euclidean wrap
    }

    #[test]
    fn integrate_across_boundary() {
        let c = two_step();
        // [5, 15]: 5s at 100 + 5s at 300 = 2000
        assert!((c.integrate(5.0, 15.0) - 2000.0).abs() < 1e-9);
        assert_eq!(c.integrate(5.0, 5.0), 0.0);
        assert!((c.mean_over(5.0, 15.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn integrate_matches_at_within_step() {
        let c = two_step();
        assert!((c.integrate(2.0, 4.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn constant_trace() {
        let c = CarbonTrace::constant(250.0);
        assert_eq!(c.at(123456.0), 250.0);
        assert!((c.mean_over(0.0, 1e6) - 250.0).abs() < 1e-9);
    }

    #[test]
    fn min_max() {
        let c = two_step();
        assert_eq!(c.min(), 100.0);
        assert_eq!(c.max(), 300.0);
    }
}
