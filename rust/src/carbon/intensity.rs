//! Carbon-intensity time series with step-wise (hourly) evaluation.

/// A carbon-intensity trace: values in gCO₂eq/kWh sampled every `step_s`
/// seconds starting at t=0. Lookups beyond the end wrap around (diurnal
/// profiles repeat), matching the paper's hourly sampling (§IV-A3).
///
/// Construction precomputes a per-step prefix-sum table so range integrals
/// ([`CarbonTrace::integrate`], [`CarbonTrace::mean_over`]) are O(1) in the
/// span length — they sit on the simulator's per-invocation hot path, which
/// previously paid an O(elapsed-steps) loop for every idle span
/// (EXPERIMENTS.md §Perf iteration 2). Mutate `values` only through
/// [`CarbonTrace::new`]; the table is derived state.
#[derive(Debug, Clone)]
pub struct CarbonTrace {
    pub step_s: f64,
    pub values: Vec<f64>,
    pub region: String,
    /// `prefix[k]` = ∫ CI over the first `k` steps of one period,
    /// in (gCO₂/kWh)·s; `prefix[values.len()]` is the full-period integral.
    prefix: Vec<f64>,
}

impl CarbonTrace {
    pub fn new(region: &str, step_s: f64, values: Vec<f64>) -> Self {
        assert!(step_s > 0.0 && !values.is_empty());
        let mut prefix = Vec::with_capacity(values.len() + 1);
        let mut acc = 0.0;
        prefix.push(0.0);
        for &v in &values {
            acc += v * step_s;
            prefix.push(acc);
        }
        CarbonTrace { step_s, values, region: region.to_string(), prefix }
    }

    /// Constant CI — the ablation baseline (no temporal signal).
    pub fn constant(ci: f64) -> Self {
        CarbonTrace::new("constant", 3600.0, vec![ci])
    }

    /// CI at time `t` (seconds from trace start). Piecewise constant per
    /// step; wraps past the end.
    #[inline]
    pub fn at(&self, t: f64) -> f64 {
        let idx = (t / self.step_s).floor() as i64;
        let n = self.values.len() as i64;
        let idx = ((idx % n) + n) % n; // euclidean wrap (handles t<0 too)
        self.values[idx as usize]
    }

    /// Antiderivative F(t) = ∫₀ᵗ CI(u) du of the periodic step function,
    /// valid for any finite `t` (negative included). O(1) via the prefix
    /// table: whole periods contribute `prefix[n]` each, the remainder is a
    /// prefix lookup plus one partial step.
    #[inline]
    fn antiderivative(&self, t: f64) -> f64 {
        let n = self.values.len();
        let period = self.step_s * n as f64;
        let cycles = (t / period).floor();
        // rem ∈ [0, period); clamp the step index against FP edge cases
        // where rem/step_s rounds up to n.
        let rem = t - cycles * period;
        let k = ((rem / self.step_s) as usize).min(n - 1);
        let partial = self.prefix[k] + (rem - k as f64 * self.step_s) * self.values[k];
        cycles * self.prefix[n] + partial
    }

    /// Integral of CI over [t0, t1] in (gCO₂eq/kWh)·s — used to carbon-weight
    /// idle energy that spans step boundaries. O(1) in the span length.
    ///
    /// Non-finite bounds (NaN/±inf) are a caller bug — the pre-prefix-sum
    /// implementation looped forever on them; now they return 0.0 (and trip
    /// a `debug_assert!` in debug builds).
    pub fn integrate(&self, t0: f64, t1: f64) -> f64 {
        debug_assert!(
            t0.is_finite() && t1.is_finite(),
            "non-finite integrate bounds [{t0}, {t1}]"
        );
        if !t0.is_finite() || !t1.is_finite() {
            return 0.0;
        }
        if t1 <= t0 {
            return 0.0;
        }
        self.antiderivative(t1) - self.antiderivative(t0)
    }

    /// Mean CI over [t0, t1]. O(1).
    pub fn mean_over(&self, t0: f64, t1: f64) -> f64 {
        if t1 <= t0 {
            return self.at(t0);
        }
        self.integrate(t0, t1) / (t1 - t0)
    }

    /// Start time of the step containing `t` — the instant the sample the
    /// feed would have delivered at `t` was taken. Used by the stale-carbon
    /// fallback to anchor its diurnal extrapolation.
    pub fn step_start(&self, t: f64) -> f64 {
        (t / self.step_s).floor() * self.step_s
    }

    pub fn duration_s(&self) -> f64 {
        self.step_s * self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_step() -> CarbonTrace {
        CarbonTrace::new("t", 10.0, vec![100.0, 300.0])
    }

    /// Reference implementation: the original step-walking loop.
    fn integrate_stepwise(c: &CarbonTrace, t0: f64, t1: f64) -> f64 {
        if t1 <= t0 {
            return 0.0;
        }
        let mut acc = 0.0;
        let mut t = t0;
        while t < t1 {
            let step_end = ((t / c.step_s).floor() + 1.0) * c.step_s;
            let seg_end = step_end.min(t1);
            acc += c.at(t) * (seg_end - t);
            t = seg_end;
        }
        acc
    }

    #[test]
    fn piecewise_constant_lookup() {
        let c = two_step();
        assert_eq!(c.at(0.0), 100.0);
        assert_eq!(c.at(9.999), 100.0);
        assert_eq!(c.at(10.0), 300.0);
    }

    #[test]
    fn wraps_around() {
        let c = two_step();
        assert_eq!(c.at(20.0), 100.0);
        assert_eq!(c.at(35.0), 300.0);
        assert_eq!(c.at(-5.0), 300.0); // euclidean wrap
    }

    #[test]
    fn integrate_across_boundary() {
        let c = two_step();
        // [5, 15]: 5s at 100 + 5s at 300 = 2000
        assert!((c.integrate(5.0, 15.0) - 2000.0).abs() < 1e-9);
        assert_eq!(c.integrate(5.0, 5.0), 0.0);
        assert!((c.mean_over(5.0, 15.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn integrate_matches_at_within_step() {
        let c = two_step();
        assert!((c.integrate(2.0, 4.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn constant_trace() {
        let c = CarbonTrace::constant(250.0);
        assert_eq!(c.at(123456.0), 250.0);
        assert!((c.mean_over(0.0, 1e6) - 250.0).abs() < 1e-9);
    }

    #[test]
    fn step_start_floors_to_step_grid() {
        let c = two_step();
        assert_eq!(c.step_start(0.0), 0.0);
        assert_eq!(c.step_start(9.999), 0.0);
        assert_eq!(c.step_start(10.0), 10.0);
        assert_eq!(c.step_start(25.0), 20.0);
        assert_eq!(c.step_start(-3.0), -10.0);
    }

    #[test]
    fn min_max() {
        let c = two_step();
        assert_eq!(c.min(), 100.0);
        assert_eq!(c.max(), 300.0);
    }

    #[test]
    fn prefix_integral_matches_stepwise_reference() {
        // The O(1) form must agree with the original O(steps) walk across
        // wraps, negative times, and sub-step spans.
        let c = CarbonTrace::new("t", 7.0, vec![120.0, 80.0, 310.0, 45.0, 200.0]);
        let probes = [
            (0.0, 3.0),
            (0.0, 7.0),
            (6.9, 7.1),
            (3.0, 40.0),
            (-12.5, 9.25),
            (-40.0, -1.0),
            (17.3, 17.3001),
            (0.0, 350.0), // 10 full periods
            (1.0, 1.0),
        ];
        for (t0, t1) in probes {
            let got = c.integrate(t0, t1);
            let want = integrate_stepwise(&c, t0, t1);
            assert!(
                (got - want).abs() <= want.abs().max(1.0) * 1e-12,
                "[{t0}, {t1}]: got {got} want {want}"
            );
        }
    }

    #[test]
    fn integrate_many_periods_is_exactly_periodic() {
        let c = two_step();
        let one_period = c.integrate(0.0, 20.0);
        // 1e6 wrapped periods — O(1), and exact multiples of the period sum.
        let many = c.integrate(0.0, 20.0 * 1e6);
        assert!((many - one_period * 1e6).abs() < one_period * 1e6 * 1e-12);
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn non_finite_bounds_integrate_to_zero() {
        let c = two_step();
        for (t0, t1) in [
            (f64::NAN, 10.0),
            (0.0, f64::NAN),
            (f64::NEG_INFINITY, 10.0),
            (0.0, f64::INFINITY),
            (f64::NEG_INFINITY, f64::INFINITY),
        ] {
            assert_eq!(c.integrate(t0, t1), 0.0, "[{t0}, {t1}]");
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "non-finite integrate bounds")]
    fn non_finite_bounds_trip_debug_assert() {
        two_step().integrate(0.0, f64::INFINITY);
    }

    #[test]
    fn nan_mean_over_does_not_hang() {
        // mean_over with reversed/NaN bounds degrades to a point lookup or
        // a 0-length integral; it must terminate either way.
        let c = two_step();
        let v = c.mean_over(10.0, 5.0);
        assert_eq!(v, c.at(10.0));
    }
}
