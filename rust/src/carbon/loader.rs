//! Electricity Maps CSV loader.
//!
//! Accepts the hourly export format: a `carbon_intensity` column (gCO₂/kWh),
//! rows in chronological order, one per hour. Extra columns are ignored.

use crate::carbon::intensity::CarbonTrace;
use crate::carbon::synth::diurnal_prior;
use crate::util::csv::Table;

/// Load an hourly CI trace from CSV. `region` labels the result.
pub fn load_csv(path: &str, region: &str) -> anyhow::Result<CarbonTrace> {
    let table = Table::load(path)?;
    from_table(&table, region)
}

pub fn from_table(table: &Table, region: &str) -> anyhow::Result<CarbonTrace> {
    let col = table
        .col("carbon_intensity")
        .ok_or_else(|| anyhow::anyhow!("missing column 'carbon_intensity'"))?;
    let mut values = Vec::with_capacity(table.rows.len());
    for (ri, row) in table.rows.iter().enumerate() {
        let v: f64 = row
            .get(col)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("row {}: bad carbon_intensity", ri + 2))?;
        anyhow::ensure!(v >= 0.0, "row {}: negative carbon intensity", ri + 2);
        values.push(v);
    }
    anyhow::ensure!(!values.is_empty(), "empty carbon trace");
    Ok(CarbonTrace::new(region, 3600.0, values))
}

/// Like [`load_csv`], but tolerates feed gaps: empty or unparsable
/// `carbon_intensity` cells are filled by extrapolating the nearest earlier
/// valid sample along the diurnal prior (the same stale-feed fallback the
/// chaos recovery path uses online). Returns the trace and the number of
/// rows that were filled.
pub fn load_csv_filled(path: &str, region: &str) -> anyhow::Result<(CarbonTrace, usize)> {
    let table = Table::load(path)?;
    from_table_filled(&table, region)
}

/// Gap-filling variant of [`from_table`]; see [`load_csv_filled`].
/// Leading gaps backfill from the first valid sample. Negative values are
/// still rejected (a present-but-wrong feed is an error, not a gap).
pub fn from_table_filled(table: &Table, region: &str) -> anyhow::Result<(CarbonTrace, usize)> {
    let col = table
        .col("carbon_intensity")
        .ok_or_else(|| anyhow::anyhow!("missing column 'carbon_intensity'"))?;
    let mut raw: Vec<Option<f64>> = Vec::with_capacity(table.rows.len());
    for (ri, row) in table.rows.iter().enumerate() {
        let v: Option<f64> = row.get(col).and_then(|s| s.parse().ok());
        if let Some(v) = v {
            anyhow::ensure!(v >= 0.0, "row {}: negative carbon intensity", ri + 2);
        }
        raw.push(v);
    }
    anyhow::ensure!(!raw.is_empty(), "empty carbon trace");
    let first_valid = raw
        .iter()
        .position(Option::is_some)
        .ok_or_else(|| anyhow::anyhow!("no valid carbon_intensity rows to fill from"))?;
    let mut filled = 0usize;
    // Rows are hourly; anchor is (value, hour index) of the nearest valid
    // sample — earlier for trailing gaps, the first valid one for leading.
    let mut anchor = (raw[first_valid].unwrap(), first_valid);
    let mut values = Vec::with_capacity(raw.len());
    for (i, v) in raw.iter().enumerate() {
        match v {
            Some(v) => {
                anchor = (*v, i);
                values.push(*v);
            }
            None => {
                filled += 1;
                let (last, j) = anchor;
                values.push(last * diurnal_prior(i as f64) / diurnal_prior(j as f64));
            }
        }
    }
    Ok((CarbonTrace::new(region, 3600.0, values), filled))
}

/// Save a trace back to the same schema.
pub fn save_csv(trace: &CarbonTrace, path: &str) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = crate::util::csv::Writer::new(
        std::io::BufWriter::new(f),
        &["hour", "carbon_intensity"],
    )?;
    for (i, v) in trace.values.iter().enumerate() {
        w.row(&[format!("{i}"), format!("{v:.3}")])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_hourly_values() {
        let t = Table::read(Cursor::new(
            "hour,carbon_intensity\n0,120.5\n1,130.0\n2,90.25\n",
        ))
        .unwrap();
        let c = from_table(&t, "test").unwrap();
        assert_eq!(c.values, vec![120.5, 130.0, 90.25]);
        assert_eq!(c.at(3700.0), 130.0);
    }

    #[test]
    fn rejects_negative_and_missing() {
        let t = Table::read(Cursor::new("carbon_intensity\n-1\n")).unwrap();
        assert!(from_table(&t, "x").is_err());
        let t = Table::read(Cursor::new("other\n1\n")).unwrap();
        assert!(from_table(&t, "x").is_err());
    }

    #[test]
    fn fills_gaps_along_diurnal_prior() {
        use crate::carbon::synth::diurnal_prior;
        // Hours 0,1 valid; 2,3 missing; 4 valid again.
        let t = Table::read(Cursor::new(
            "hour,carbon_intensity\n0,400\n1,410\n2,\n3,x\n4,395\n",
        ))
        .unwrap();
        let (c, filled) = from_table_filled(&t, "gap").unwrap();
        assert_eq!(filled, 2);
        assert_eq!(c.values.len(), 5);
        assert_eq!(c.values[1], 410.0);
        assert_eq!(c.values[4], 395.0);
        // Gaps extrapolate the hour-1 anchor along the prior ratio.
        assert_eq!(c.values[2], 410.0 * diurnal_prior(2.0) / diurnal_prior(1.0));
        assert_eq!(c.values[3], 410.0 * diurnal_prior(3.0) / diurnal_prior(1.0));
    }

    #[test]
    fn leading_gaps_backfill_from_first_valid() {
        let t =
            Table::read(Cursor::new("hour,carbon_intensity\n0,\n1,\n2,300\n")).unwrap();
        let (c, filled) = from_table_filled(&t, "lead").unwrap();
        assert_eq!(filled, 2);
        assert_eq!(c.values[2], 300.0);
        assert!(c.values[0] > 0.0 && c.values[1] > 0.0);
        // All-gap tables are still an error — nothing to fill from.
        let t = Table::read(Cursor::new("hour,carbon_intensity\n0,\n1,\n")).unwrap();
        assert!(from_table_filled(&t, "none").is_err());
        // Negative values are rejected even in filling mode.
        let t = Table::read(Cursor::new("carbon_intensity\n-5\n")).unwrap();
        assert!(from_table_filled(&t, "neg").is_err());
    }

    #[test]
    fn roundtrip() {
        let c = crate::carbon::synth::synth_region(
            crate::carbon::synth::Region::SolarHeavy,
            1,
            4,
        );
        let path = std::env::temp_dir().join("lace_rl_ci_roundtrip.csv");
        let path = path.to_str().unwrap();
        save_csv(&c, path).unwrap();
        let loaded = load_csv(path, "rt").unwrap();
        assert_eq!(loaded.values.len(), c.values.len());
        for (a, b) in c.values.iter().zip(loaded.values.iter()) {
            assert!((a - b).abs() < 1e-3);
        }
        let _ = std::fs::remove_file(path);
    }
}
