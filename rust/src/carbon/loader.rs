//! Electricity Maps CSV loader.
//!
//! Accepts the hourly export format: a `carbon_intensity` column (gCO₂/kWh),
//! rows in chronological order, one per hour. Extra columns are ignored.

use crate::carbon::intensity::CarbonTrace;
use crate::util::csv::Table;

/// Load an hourly CI trace from CSV. `region` labels the result.
pub fn load_csv(path: &str, region: &str) -> anyhow::Result<CarbonTrace> {
    let table = Table::load(path)?;
    from_table(&table, region)
}

pub fn from_table(table: &Table, region: &str) -> anyhow::Result<CarbonTrace> {
    let col = table
        .col("carbon_intensity")
        .ok_or_else(|| anyhow::anyhow!("missing column 'carbon_intensity'"))?;
    let mut values = Vec::with_capacity(table.rows.len());
    for (ri, row) in table.rows.iter().enumerate() {
        let v: f64 = row
            .get(col)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("row {}: bad carbon_intensity", ri + 2))?;
        anyhow::ensure!(v >= 0.0, "row {}: negative carbon intensity", ri + 2);
        values.push(v);
    }
    anyhow::ensure!(!values.is_empty(), "empty carbon trace");
    Ok(CarbonTrace::new(region, 3600.0, values))
}

/// Save a trace back to the same schema.
pub fn save_csv(trace: &CarbonTrace, path: &str) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = crate::util::csv::Writer::new(
        std::io::BufWriter::new(f),
        &["hour", "carbon_intensity"],
    )?;
    for (i, v) in trace.values.iter().enumerate() {
        w.row(&[format!("{i}"), format!("{v:.3}")])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_hourly_values() {
        let t = Table::read(Cursor::new(
            "hour,carbon_intensity\n0,120.5\n1,130.0\n2,90.25\n",
        ))
        .unwrap();
        let c = from_table(&t, "test").unwrap();
        assert_eq!(c.values, vec![120.5, 130.0, 90.25]);
        assert_eq!(c.at(3700.0), 130.0);
    }

    #[test]
    fn rejects_negative_and_missing() {
        let t = Table::read(Cursor::new("carbon_intensity\n-1\n")).unwrap();
        assert!(from_table(&t, "x").is_err());
        let t = Table::read(Cursor::new("other\n1\n")).unwrap();
        assert!(from_table(&t, "x").is_err());
    }

    #[test]
    fn roundtrip() {
        let c = crate::carbon::synth::synth_region(
            crate::carbon::synth::Region::SolarHeavy,
            1,
            4,
        );
        let path = std::env::temp_dir().join("lace_rl_ci_roundtrip.csv");
        let path = path.to_str().unwrap();
        save_csv(&c, path).unwrap();
        let loaded = load_csv(path, "rt").unwrap();
        assert_eq!(loaded.values.len(), c.values.len());
        for (a, b) in c.values.iter().zip(loaded.values.iter()) {
            assert!((a - b).abs() < 1e-3);
        }
        let _ = std::fs::remove_file(path);
    }
}
