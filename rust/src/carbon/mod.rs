//! Grid carbon-intensity substrate (Electricity Maps substitute).
//!
//! The paper consumes hourly carbon-intensity (CI) traces in gCO₂eq/kWh and
//! assumes CI is constant within an hour (§II-B). [`synth`] generates the
//! three anonymized region archetypes of Fig. 3a (solar duck-curve,
//! fossil-heavy flat, hydro-dominated low); [`loader`] reads real
//! Electricity Maps CSV exports.

pub mod intensity;
pub mod loader;
pub mod synth;

pub use intensity::CarbonTrace;
pub use synth::{synth_region, Region};
