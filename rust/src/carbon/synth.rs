//! Synthetic carbon-intensity archetypes (Fig. 3a substitute).
//!
//! Three anonymized region profiles capturing the variability the paper
//! exploits: a solar-heavy grid with a pronounced midday "duck curve" dip,
//! a fossil-heavy grid that is high and flat with evening peaks, and a
//! hydro/nuclear grid that is low and stable. Values are plausible
//! gCO₂eq/kWh magnitudes from public Electricity Maps data.

use crate::carbon::intensity::CarbonTrace;
use crate::util::rng::Rng;

/// Region archetype (names anonymized as in the paper's Fig. 3a).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    /// High solar penetration: deep midday dip, morning/evening shoulders.
    SolarHeavy,
    /// Coal/gas dominated: high baseline, mild evening peak.
    FossilHeavy,
    /// Hydro/nuclear dominated: low, almost flat.
    HydroLow,
}

impl Region {
    pub const ALL: [Region; 3] = [Region::SolarHeavy, Region::FossilHeavy, Region::HydroLow];

    pub fn name(&self) -> &'static str {
        match self {
            Region::SolarHeavy => "region-A (solar-heavy)",
            Region::FossilHeavy => "region-B (fossil-heavy)",
            Region::HydroLow => "region-C (hydro-low)",
        }
    }

    pub fn from_name(s: &str) -> Option<Region> {
        match s.to_ascii_lowercase().as_str() {
            "solar" | "region-a" | "a" => Some(Region::SolarHeavy),
            "fossil" | "region-b" | "b" => Some(Region::FossilHeavy),
            "hydro" | "region-c" | "c" => Some(Region::HydroLow),
            _ => None,
        }
    }
}

/// Hourly CI for `days` days in the given region, with mild day-to-day noise.
pub fn synth_region(region: Region, days: usize, seed: u64) -> CarbonTrace {
    let mut rng = Rng::new(seed ^ (region as u64).wrapping_mul(0x9E37_79B9));
    let mut values = Vec::with_capacity(days * 24);
    for _day in 0..days {
        // Day-level weather factor (cloud cover / wind).
        let weather = rng.range(0.85, 1.15);
        for hour in 0..24 {
            let h = hour as f64;
            let ci = match region {
                Region::SolarHeavy => {
                    // Baseline 420; solar carves out up to ~300 between
                    // 07:00 and 19:00, deepest at 13:00.
                    let solar = if (7.0..19.0).contains(&h) {
                        let x = (h - 13.0) / 6.0; // -1..1 across the window
                        (1.0 - x * x).max(0.0) * 310.0 * weather
                    } else {
                        0.0
                    };
                    420.0 - solar
                }
                Region::FossilHeavy => {
                    // High base with a demand-driven evening bump.
                    let evening = (-(h - 19.0) * (h - 19.0) / 8.0).exp() * 60.0;
                    let morning = (-(h - 8.0) * (h - 8.0) / 10.0).exp() * 30.0;
                    (620.0 + evening + morning) * weather
                }
                Region::HydroLow => 45.0 + 12.0 * ((h - 18.0) / 24.0
                    * std::f64::consts::TAU)
                    .sin()
                    .abs()
                    * weather,
            };
            let noise = rng.normal(0.0, ci * 0.03);
            values.push((ci + noise).max(5.0));
        }
    }
    CarbonTrace::new(region.name(), 3600.0, values)
}

/// Normalized diurnal carbon-intensity prior: the noise-free SolarHeavy
/// shape divided by its daily mean, so the prior averages 1.0 over a day.
/// The stale-carbon fallback (`chaos::recovery::fallback_ci`) uses the
/// *ratio* of this prior between two times of day to extrapolate a frozen
/// feed sample along the expected duck curve. `hour` wraps modulo 24 and
/// accepts negative values.
pub fn diurnal_prior(hour: f64) -> f64 {
    let h = hour.rem_euclid(24.0);
    let solar = if (7.0..19.0).contains(&h) {
        let x = (h - 13.0) / 6.0;
        (1.0 - x * x).max(0.0) * 310.0
    } else {
        0.0
    };
    // Daily mean of the shape: 420 − (∫ solar dh)/24 = 420 − 2480/24.
    let mean = 420.0 - 2480.0 / 24.0;
    (420.0 - solar) / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_prior_dips_midday_and_averages_one() {
        assert!(diurnal_prior(13.0) < diurnal_prior(2.0));
        assert!(diurnal_prior(13.0) < diurnal_prior(20.0));
        // Wraps: hour 25 ≡ hour 1, negative hours wrap too.
        assert_eq!(diurnal_prior(25.0), diurnal_prior(1.0));
        assert_eq!(diurnal_prior(-1.0), diurnal_prior(23.0));
        // Mean over a day ≈ 1 (trapezoid-free: the shape is piecewise
        // smooth, so a fine Riemann sum suffices).
        let n = 24 * 3600;
        let mean: f64 = (0..n).map(|i| diurnal_prior(i as f64 / 3600.0)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 1e-3, "mean={mean}");
    }

    #[test]
    fn solar_duck_curve_dips_midday() {
        let c = synth_region(Region::SolarHeavy, 1, 1);
        let midday = c.at(13.0 * 3600.0);
        let night = c.at(2.0 * 3600.0);
        assert!(
            midday < night * 0.6,
            "midday={midday} should be well below night={night}"
        );
    }

    #[test]
    fn fossil_is_high_and_flat() {
        let c = synth_region(Region::FossilHeavy, 1, 1);
        assert!(c.min() > 500.0);
        assert!(c.max() / c.min() < 1.5);
    }

    #[test]
    fn hydro_is_low() {
        let c = synth_region(Region::HydroLow, 1, 1);
        assert!(c.max() < 100.0);
    }

    #[test]
    fn ordering_between_regions() {
        let s = synth_region(Region::SolarHeavy, 2, 3);
        let f = synth_region(Region::FossilHeavy, 2, 3);
        let h = synth_region(Region::HydroLow, 2, 3);
        let mean = |c: &CarbonTrace| c.values.iter().sum::<f64>() / c.values.len() as f64;
        assert!(mean(&h) < mean(&s) && mean(&s) < mean(&f));
    }

    #[test]
    fn deterministic_and_positive() {
        let a = synth_region(Region::SolarHeavy, 3, 9);
        let b = synth_region(Region::SolarHeavy, 3, 9);
        assert_eq!(a.values, b.values);
        assert!(a.values.iter().all(|&v| v > 0.0));
        assert_eq!(a.values.len(), 72);
    }
}
