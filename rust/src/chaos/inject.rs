//! The injector: stateless fault queries keyed on `(seed, func, t, attempt)`.
//!
//! Both stacks consult the same injector at the same logical points
//! (cold-pod spawn, decision-time carbon lookup, decision latency), and
//! every stochastic draw re-derives its RNG from the event identity — no
//! mutable state is shared across events. That makes fault outcomes
//! independent of invocation interleaving, which is what keeps the
//! function-sharded simulator bit-identical to sequential replay under an
//! active plan (`rust/tests/property_chaos.rs`). The only mutable state is
//! the wall-clock driver-stall counter, which never feeds back into
//! simulated quantities.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::carbon::intensity::CarbonTrace;
use crate::chaos::plan::{Fault, FaultPlan};
use crate::chaos::recovery::{self, RecoveryConfig};
use crate::util::rng::Rng;

/// Per-event RNG: hash the event identity into a fresh generator. Pure,
/// so identical events draw identical faults regardless of ordering.
fn event_rng(seed: u64, func: u32, t: f64, attempt: u32) -> Rng {
    let mut h = seed;
    h ^= (u64::from(func) + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^= t.to_bits().wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h ^= (u64::from(attempt) + 1).wrapping_mul(0x94D0_49BB_1331_11EB);
    Rng::new(h)
}

/// Interprets a [`FaultPlan`] for the engine, router, and driver.
#[derive(Debug)]
pub struct ChaosInjector {
    plan: FaultPlan,
    /// Carbon-outage windows `(from, until)`.
    outages: Vec<(f64, f64)>,
    /// Spawn-failure windows `(from, until, p)`.
    spawn_windows: Vec<(f64, f64, f64)>,
    /// Decision-delay windows `(from, until, delay_s)`.
    delay_windows: Vec<(f64, f64, f64)>,
    /// Driver stalls `(at, dur)`, sorted by trigger time.
    stalls: Vec<(f64, f64)>,
    /// Wall-clock-only count of stalls the driver actually hit.
    stalls_hit: AtomicU64,
}

impl ChaosInjector {
    /// Partition a plan's faults into per-class window lists.
    pub fn new(plan: FaultPlan) -> Self {
        let mut outages = Vec::new();
        let mut spawn_windows = Vec::new();
        let mut delay_windows = Vec::new();
        let mut stalls = Vec::new();
        for f in &plan.faults {
            match *f {
                Fault::CarbonOutage { from_s, until_s } => outages.push((from_s, until_s)),
                Fault::SpawnFailure { from_s, until_s, p } => {
                    spawn_windows.push((from_s, until_s, p))
                }
                Fault::DecisionDelay { from_s, until_s, delay_s } => {
                    delay_windows.push((from_s, until_s, delay_s))
                }
                Fault::DriverStall { at_s, dur_s } => stalls.push((at_s, dur_s)),
            }
        }
        stalls.sort_by(|a, b| a.0.total_cmp(&b.0));
        ChaosInjector {
            plan,
            outages,
            spawn_windows,
            delay_windows,
            stalls,
            stalls_hit: AtomicU64::new(0),
        }
    }

    /// The plan this injector interprets.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// The plan's recovery knobs.
    pub fn recovery(&self) -> &RecoveryConfig {
        &self.plan.recovery
    }

    /// True when the plan schedules nothing (injection is a no-op).
    pub fn is_empty(&self) -> bool {
        self.plan.is_empty()
    }

    /// Spawn-failure query for a cold start of `func` at virtual time `t`:
    /// returns `(total backoff delay, failed attempts)`. `(0.0, 0)` outside
    /// any window or when the first attempt succeeds.
    pub fn spawn_delay(&self, func: u32, t: f64) -> (f64, u32) {
        let p = self
            .spawn_windows
            .iter()
            .find(|(from, until, _)| t >= *from && t < *until)
            .map(|&(_, _, p)| p);
        let Some(p) = p else { return (0.0, 0) };
        let rc = self.recovery();
        let mut delay = 0.0;
        let mut attempt = 0u32;
        while attempt < rc.max_spawn_retries {
            let mut rng = event_rng(self.plan.seed, func, t, attempt);
            // rng.f64() ∈ [0, 1), so p = 1.0 always fails — the retry
            // budget is exhausted deterministically.
            if rng.f64() >= p {
                break;
            }
            delay += recovery::backoff_delay(rc, rng.f64(), attempt);
            attempt += 1;
        }
        (delay, attempt)
    }

    /// If the carbon feed is down at `t`, the outage's start time (when
    /// the last fresh sample arrived); `None` when the feed is healthy.
    pub fn stale_since(&self, t: f64) -> Option<f64> {
        self.outages
            .iter()
            .find(|(from, until)| t >= *from && t < *until)
            .map(|&(from, _)| from)
    }

    /// The degraded carbon estimate during an outage that began at
    /// `outage_start` (from [`ChaosInjector::stale_since`]).
    pub fn fallback_ci(&self, ci: &CarbonTrace, t: f64, outage_start: f64) -> f64 {
        recovery::fallback_ci(ci, t, outage_start)
    }

    /// True when the injected decision latency at `t` exceeds the recovery
    /// timeout — the decision is discarded and the fallback action applies.
    pub fn decision_degraded(&self, t: f64) -> bool {
        self.delay_windows
            .iter()
            .any(|(from, until, d)| t >= *from && t < *until && *d > self.recovery().decision_timeout_s)
    }

    /// Driver-stall schedule, sorted by trigger time.
    pub fn stall_windows(&self) -> &[(f64, f64)] {
        &self.stalls
    }

    /// Record that the driver hit one stall (wall-clock accounting only).
    pub fn note_stall(&self) {
        self.stalls_hit.fetch_add(1, Ordering::Relaxed);
    }

    /// Stalls the driver hit this run.
    pub fn stalls_hit(&self) -> u64 {
        self.stalls_hit.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window_plan(p: f64) -> FaultPlan {
        FaultPlan {
            seed: 7,
            faults: vec![
                Fault::SpawnFailure { from_s: 100.0, until_s: 200.0, p },
                Fault::CarbonOutage { from_s: 300.0, until_s: 400.0 },
                Fault::DecisionDelay { from_s: 500.0, until_s: 600.0, delay_s: 2.0 },
                Fault::DriverStall { at_s: 50.0, dur_s: 0.1 },
            ],
            recovery: RecoveryConfig::default(),
        }
    }

    #[test]
    fn spawn_delay_outside_window_is_zero() {
        let inj = ChaosInjector::new(window_plan(1.0));
        assert_eq!(inj.spawn_delay(3, 99.0), (0.0, 0));
        assert_eq!(inj.spawn_delay(3, 200.0), (0.0, 0));
    }

    #[test]
    fn certain_failure_exhausts_retry_budget() {
        let inj = ChaosInjector::new(window_plan(1.0));
        let (delay, attempts) = inj.spawn_delay(3, 150.0);
        assert_eq!(attempts, RecoveryConfig::default().max_spawn_retries);
        assert!(delay > 0.0);
    }

    #[test]
    fn zero_probability_never_fails() {
        let inj = ChaosInjector::new(window_plan(0.0));
        assert_eq!(inj.spawn_delay(3, 150.0), (0.0, 0));
    }

    #[test]
    fn spawn_delay_is_a_pure_function_of_the_event() {
        let a = ChaosInjector::new(window_plan(0.5));
        let b = ChaosInjector::new(window_plan(0.5));
        for func in 0..20u32 {
            let t = 100.0 + f64::from(func);
            assert_eq!(a.spawn_delay(func, t), b.spawn_delay(func, t));
            // Re-querying the same injector is also stable (statelessness).
            assert_eq!(a.spawn_delay(func, t), a.spawn_delay(func, t));
        }
    }

    #[test]
    fn stale_and_degraded_windows() {
        let inj = ChaosInjector::new(window_plan(1.0));
        assert_eq!(inj.stale_since(350.0), Some(300.0));
        assert_eq!(inj.stale_since(250.0), None);
        assert!(inj.decision_degraded(550.0)); // 2.0 s > 1.0 s timeout
        assert!(!inj.decision_degraded(450.0));
    }

    #[test]
    fn sub_timeout_delay_does_not_degrade() {
        let mut plan = window_plan(1.0);
        plan.faults = vec![Fault::DecisionDelay { from_s: 0.0, until_s: 10.0, delay_s: 0.5 }];
        let inj = ChaosInjector::new(plan);
        assert!(!inj.decision_degraded(5.0));
    }
}
