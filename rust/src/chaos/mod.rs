//! Deterministic fault injection and resilience (DESIGN.md §10).
//!
//! The serving and simulation stacks assume a perfect world: the
//! carbon-intensity feed is always fresh, pods always spawn, policy
//! decisions always return in time. Emission-aware platforms must stay
//! correct when those assumptions break (GreenWhisk), and carbon-aware
//! decisions degrade sharply when the intensity signal is wrong (EcoLife).
//! This module makes failure a first-class, *measured* input:
//!
//! * [`plan::FaultPlan`] — a seeded, JSON-serializable schedule of fault
//!   windows: carbon-feed outages, pod-spawn failures with probability `p`,
//!   decision-latency spikes, trace-driver stalls.
//! * [`inject::ChaosInjector`] — stateless, hash-keyed queries the engine,
//!   router, and driver consult at their injection points. Every stochastic
//!   draw is a pure function of `(plan seed, function id, virtual time,
//!   attempt)`, so the same plan replays bit-identically across runs, shard
//!   counts, and both stacks.
//! * [`recovery`] — the graceful-degradation half: exponential-backoff
//!   pod-spawn retry with jitter from [`crate::util::rng`], stale-carbon
//!   fallback to the last-known sample scaled by a diurnal prior
//!   ([`crate::carbon::synth::diurnal_prior`]), and a decision timeout that
//!   degrades to the static fixed-keep-alive action.
//! * [`report`] — degraded-mode accounting: per-function
//!   [`report::ChaosCounters`] folded through the same id-order merge
//!   contract as [`crate::simulator::metrics::SimMetrics`], plus the
//!   `CHAOS_SUMMARY` line the tooling parses.
//!
//! Invariants (property-tested in `rust/tests/property_chaos.rs`):
//! same plan + seed ⇒ bit-identical reports across runs and shard counts;
//! no plan (or an empty one) ⇒ behavior byte-identical to a run without
//! this module.

pub mod inject;
pub mod plan;
pub mod recovery;
pub mod report;

pub use inject::ChaosInjector;
pub use plan::{Fault, FaultPlan};
pub use recovery::RecoveryConfig;
pub use report::{ChaosCounters, ChaosReport};
