//! The `FaultPlan` DSL: a seeded, serializable schedule of fault windows.
//!
//! A plan is data, not behavior — the [`crate::chaos::ChaosInjector`]
//! interprets it. Serialization goes through [`crate::util::json`] so plans
//! can be saved, diffed, and replayed across hosts (`lace-rl chaos
//! --save-plan` / `--plan`). Seeds round-trip through f64 JSON numbers, so
//! keep them below 2⁵³ (every seed in this repo is).

use crate::chaos::recovery::RecoveryConfig;
use crate::util::json::Json;

/// One scheduled fault. All times are virtual workload seconds (the same
/// clock as trace arrivals), so a plan means the same thing to the
/// simulator and to the online coordinator replaying at any speedup.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// The carbon-intensity feed stops updating during `[from_s, until_s)`:
    /// decisions see the stale-fallback estimate instead of the live value.
    /// Accounting always uses the true trace — only the *signal* degrades.
    CarbonOutage {
        /// Window start (virtual s).
        from_s: f64,
        /// Window end (virtual s, exclusive).
        until_s: f64,
    },
    /// Pod spawns during the window fail independently with probability
    /// `p`; each failed attempt costs one backoff delay (recovery policy)
    /// before the next attempt. The spawn always succeeds within the
    /// retry budget — no invocation is dropped.
    SpawnFailure {
        /// Window start (virtual s).
        from_s: f64,
        /// Window end (virtual s, exclusive).
        until_s: f64,
        /// Per-attempt failure probability in [0, 1].
        p: f64,
    },
    /// Keep-alive decisions issued during the window take `delay_s` extra
    /// seconds; past the recovery timeout the decision is discarded and
    /// the static fallback action applies.
    DecisionDelay {
        /// Window start (virtual s).
        from_s: f64,
        /// Window end (virtual s, exclusive).
        until_s: f64,
        /// Injected decision latency (s).
        delay_s: f64,
    },
    /// The trace driver stalls for `dur_s` wall-clock seconds before
    /// sending the first invocation at or after `at_s` (paced replay only;
    /// max-speed replay counts the stall without sleeping).
    DriverStall {
        /// Virtual time the stall triggers at.
        at_s: f64,
        /// Wall-clock stall duration (s).
        dur_s: f64,
    },
}

/// A complete fault schedule plus the recovery policy parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for every stochastic draw the plan induces (spawn-failure
    /// Bernoulli trials, backoff jitter). Same seed ⇒ same faults.
    pub seed: u64,
    /// The scheduled faults, in any order.
    pub faults: Vec<Fault>,
    /// Recovery-policy knobs (retry budget, backoff, decision timeout).
    pub recovery: RecoveryConfig,
}

impl FaultPlan {
    /// A plan with no faults: installing it is byte-identical to
    /// installing no plan at all (property-tested).
    pub fn empty(seed: u64) -> Self {
        FaultPlan { seed, faults: Vec::new(), recovery: RecoveryConfig::default() }
    }

    /// The canned smoke/sweep plan: fault windows positioned inside the
    /// workload span `[t0, t1]`, scaled by `intensity` ∈ [0, 1].
    /// Intensity 0 is the empty plan; intensity 1 exercises every fault
    /// class (spawn failures at p=1, a long carbon outage, decision delays
    /// past the recovery timeout, one driver stall).
    pub fn canned(seed: u64, t0: f64, t1: f64, intensity: f64) -> Self {
        let x = intensity.clamp(0.0, 1.0);
        let mut faults = Vec::new();
        if x > 0.0 {
            let span = (t1 - t0).max(1.0);
            faults.push(Fault::SpawnFailure {
                from_s: t0,
                until_s: t0 + 0.40 * span,
                p: x,
            });
            faults.push(Fault::CarbonOutage {
                from_s: t0 + 0.45 * span,
                until_s: t0 + (0.45 + 0.30 * x) * span,
            });
            faults.push(Fault::DecisionDelay {
                from_s: t0 + 0.80 * span,
                until_s: t1 + 120.0,
                delay_s: 2.5 * x,
            });
            faults.push(Fault::DriverStall { at_s: t0 + 0.50 * span, dur_s: 0.05 });
        }
        FaultPlan { seed, faults, recovery: RecoveryConfig::default() }
    }

    /// True when the plan schedules nothing.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Total carbon-outage seconds within `[0, t_end]` — the time the
    /// stale-carbon fallback was the decision signal.
    pub fn outage_seconds(&self, t_end: f64) -> f64 {
        self.faults
            .iter()
            .map(|f| match f {
                Fault::CarbonOutage { from_s, until_s } => {
                    (until_s.min(t_end) - from_s.max(0.0)).max(0.0)
                }
                _ => 0.0,
            })
            .sum()
    }

    /// Serialize to the JSON schema documented in EXPERIMENTS.md.
    pub fn to_json(&self) -> Json {
        let faults = self
            .faults
            .iter()
            .map(|f| match *f {
                Fault::CarbonOutage { from_s, until_s } => Json::obj(vec![
                    ("kind", "carbon-outage".into()),
                    ("from_s", from_s.into()),
                    ("until_s", until_s.into()),
                ]),
                Fault::SpawnFailure { from_s, until_s, p } => Json::obj(vec![
                    ("kind", "spawn-failure".into()),
                    ("from_s", from_s.into()),
                    ("until_s", until_s.into()),
                    ("p", p.into()),
                ]),
                Fault::DecisionDelay { from_s, until_s, delay_s } => Json::obj(vec![
                    ("kind", "decision-delay".into()),
                    ("from_s", from_s.into()),
                    ("until_s", until_s.into()),
                    ("delay_s", delay_s.into()),
                ]),
                Fault::DriverStall { at_s, dur_s } => Json::obj(vec![
                    ("kind", "driver-stall".into()),
                    ("at_s", at_s.into()),
                    ("dur_s", dur_s.into()),
                ]),
            })
            .collect();
        Json::obj(vec![
            ("seed", self.seed.into()),
            ("recovery", self.recovery.to_json()),
            ("faults", Json::Arr(faults)),
        ])
    }

    /// Parse a plan from its JSON form.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let seed = j
            .get("seed")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("fault plan: missing numeric 'seed'"))?
            as u64;
        let recovery = match j.get("recovery") {
            Some(r) => RecoveryConfig::from_json(r)?,
            None => RecoveryConfig::default(),
        };
        let mut faults = Vec::new();
        for (i, f) in j
            .get("faults")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("fault plan: missing 'faults' array"))?
            .iter()
            .enumerate()
        {
            let num = |key: &str| -> anyhow::Result<f64> {
                f.get(key)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("fault {i}: missing numeric '{key}'"))
            };
            let kind = f
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("fault {i}: missing 'kind'"))?;
            faults.push(match kind {
                "carbon-outage" => Fault::CarbonOutage {
                    from_s: num("from_s")?,
                    until_s: num("until_s")?,
                },
                "spawn-failure" => {
                    let p = num("p")?;
                    anyhow::ensure!((0.0..=1.0).contains(&p), "fault {i}: p out of [0,1]");
                    Fault::SpawnFailure { from_s: num("from_s")?, until_s: num("until_s")?, p }
                }
                "decision-delay" => Fault::DecisionDelay {
                    from_s: num("from_s")?,
                    until_s: num("until_s")?,
                    delay_s: num("delay_s")?,
                },
                "driver-stall" => {
                    Fault::DriverStall { at_s: num("at_s")?, dur_s: num("dur_s")? }
                }
                other => anyhow::bail!("fault {i}: unknown kind '{other}'"),
            });
        }
        Ok(FaultPlan { seed, faults, recovery })
    }

    /// Write the plan as pretty-enough single-line JSON.
    pub fn save(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        Ok(())
    }

    /// Load a plan saved by [`FaultPlan::save`].
    pub fn load(path: &str) -> anyhow::Result<Self> {
        let src = std::fs::read_to_string(path)?;
        let j = Json::parse(src.trim())
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_preserves_plan() {
        let plan = FaultPlan::canned(42, 100.0, 1100.0, 0.7);
        let j = plan.to_json().to_string();
        let back = FaultPlan::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn intensity_zero_is_empty() {
        assert!(FaultPlan::canned(1, 0.0, 1000.0, 0.0).is_empty());
        assert!(!FaultPlan::canned(1, 0.0, 1000.0, 0.1).is_empty());
    }

    #[test]
    fn outage_seconds_clip_to_horizon() {
        let plan = FaultPlan {
            seed: 0,
            faults: vec![Fault::CarbonOutage { from_s: 100.0, until_s: 300.0 }],
            recovery: RecoveryConfig::default(),
        };
        assert_eq!(plan.outage_seconds(1000.0), 200.0);
        assert_eq!(plan.outage_seconds(200.0), 100.0);
        assert_eq!(plan.outage_seconds(50.0), 0.0);
    }

    #[test]
    fn rejects_bad_plans() {
        for src in [
            r#"{"faults": []}"#,
            r#"{"seed": 1}"#,
            r#"{"seed": 1, "faults": [{"kind": "bogus"}]}"#,
            r#"{"seed": 1, "faults": [{"kind": "spawn-failure", "from_s": 0, "until_s": 1, "p": 2.0}]}"#,
        ] {
            assert!(FaultPlan::from_json(&Json::parse(src).unwrap()).is_err(), "{src}");
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let plan = FaultPlan::canned(9, 0.0, 500.0, 1.0);
        let path = std::env::temp_dir().join("lace_rl_fault_plan_rt.json");
        let path = path.to_str().unwrap();
        plan.save(path).unwrap();
        assert_eq!(FaultPlan::load(path).unwrap(), plan);
        let _ = std::fs::remove_file(path);
    }
}
