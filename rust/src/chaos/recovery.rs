//! Recovery policies: what the stack does *instead* of failing.
//!
//! Three degradation paths, one per fault class:
//!
//! * **Spawn retry** — exponential backoff with multiplicative jitter;
//!   delays come out of [`backoff_delay`] and are charged as extra
//!   cold-start latency.
//! * **Stale-carbon fallback** — [`fallback_ci`] extrapolates the
//!   last-known intensity sample along the diurnal prior
//!   ([`crate::carbon::synth::diurnal_prior`]), so a feed outage at noon
//!   doesn't freeze a solar-dip value into the evening ramp.
//! * **Decision timeout** — handled by the injector/caller: a decision
//!   slower than [`RecoveryConfig::decision_timeout_s`] is discarded and
//!   the static [`RecoveryConfig::fallback_action`] keep-alive applies.

use crate::carbon::intensity::CarbonTrace;
use crate::carbon::synth::diurnal_prior;
use crate::util::json::Json;

/// Knobs for the three recovery paths. Serialized inside the
/// [`crate::chaos::FaultPlan`] so a plan fully determines behavior.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryConfig {
    /// Maximum extra spawn attempts after the first failure.
    pub max_spawn_retries: u32,
    /// Backoff delay of the first retry (seconds).
    pub backoff_base_s: f64,
    /// Upper bound on a single backoff delay (seconds).
    pub backoff_cap_s: f64,
    /// Jitter fraction: each delay is scaled by `1 + jitter_frac·u`,
    /// `u ∈ [0, 1)` drawn from the plan-seeded stream.
    pub jitter_frac: f64,
    /// Decisions slower than this degrade to the fallback action (seconds).
    pub decision_timeout_s: f64,
    /// Index into [`crate::KEEP_ALIVE_ACTIONS`] used when degraded
    /// (default: the 60 s Huawei production timeout).
    pub fallback_action: usize,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig {
            max_spawn_retries: 4,
            backoff_base_s: 0.5,
            backoff_cap_s: 8.0,
            jitter_frac: 0.5,
            decision_timeout_s: 1.0,
            fallback_action: 4,
        }
    }
}

impl RecoveryConfig {
    /// Serialize for embedding in a fault plan.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("max_spawn_retries", u64::from(self.max_spawn_retries).into()),
            ("backoff_base_s", self.backoff_base_s.into()),
            ("backoff_cap_s", self.backoff_cap_s.into()),
            ("jitter_frac", self.jitter_frac.into()),
            ("decision_timeout_s", self.decision_timeout_s.into()),
            ("fallback_action", (self.fallback_action as u64).into()),
        ])
    }

    /// Parse; absent keys keep their defaults so plans stay forward-readable.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = RecoveryConfig::default();
        let num = |key: &str, fallback: f64| j.get(key).and_then(Json::as_f64).unwrap_or(fallback);
        let cfg = RecoveryConfig {
            max_spawn_retries: num("max_spawn_retries", f64::from(d.max_spawn_retries)) as u32,
            backoff_base_s: num("backoff_base_s", d.backoff_base_s),
            backoff_cap_s: num("backoff_cap_s", d.backoff_cap_s),
            jitter_frac: num("jitter_frac", d.jitter_frac),
            decision_timeout_s: num("decision_timeout_s", d.decision_timeout_s),
            fallback_action: num("fallback_action", d.fallback_action as f64) as usize,
        };
        anyhow::ensure!(
            cfg.fallback_action < crate::KEEP_ALIVE_ACTIONS.len(),
            "recovery: fallback_action {} out of range",
            cfg.fallback_action
        );
        Ok(cfg)
    }
}

/// Backoff delay for retry number `attempt` (0-based): `min(base·2^attempt,
/// cap) · (1 + jitter_frac·jitter01)` with `jitter01 ∈ [0, 1)` supplied by
/// the caller from the plan-seeded stream — the function itself is pure.
pub fn backoff_delay(cfg: &RecoveryConfig, jitter01: f64, attempt: u32) -> f64 {
    let base = (cfg.backoff_base_s * f64::powi(2.0, attempt as i32)).min(cfg.backoff_cap_s);
    base * (1.0 + cfg.jitter_frac * jitter01)
}

/// Stale-carbon estimate at time `t` given the feed froze at
/// `outage_start`: the last sample the feed delivered (the step containing
/// `outage_start`), scaled by the ratio of the diurnal prior now vs. then.
/// Floored at 1 gCO₂/kWh so downstream cost ratios stay finite.
pub fn fallback_ci(ci: &CarbonTrace, t: f64, outage_start: f64) -> f64 {
    let last_known = ci.at(outage_start);
    let t_last = ci.step_start(outage_start);
    (last_known * diurnal_prior(t / 3600.0) / diurnal_prior(t_last / 3600.0)).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_then_caps() {
        let cfg = RecoveryConfig::default();
        assert_eq!(backoff_delay(&cfg, 0.0, 0), 0.5);
        assert_eq!(backoff_delay(&cfg, 0.0, 1), 1.0);
        assert_eq!(backoff_delay(&cfg, 0.0, 2), 2.0);
        assert_eq!(backoff_delay(&cfg, 0.0, 10), 8.0); // capped
    }

    #[test]
    fn jitter_scales_multiplicatively() {
        let cfg = RecoveryConfig::default();
        let dry = backoff_delay(&cfg, 0.0, 1);
        let wet = backoff_delay(&cfg, 0.999, 1);
        assert!(wet > dry && wet < dry * (1.0 + cfg.jitter_frac));
    }

    #[test]
    fn fallback_tracks_diurnal_shape() {
        // Constant trace: the prior ratio is the only signal. An outage
        // starting in the solar dip (13:00) should extrapolate *upward*
        // into the evening (20:00), not freeze the dip value.
        let ci = CarbonTrace::constant(300.0);
        let est_evening = fallback_ci(&ci, 20.0 * 3600.0, 13.0 * 3600.0);
        assert!(est_evening > 300.0, "got {est_evening}");
        // Extrapolating within the same hour is a no-op.
        let same = fallback_ci(&ci, 13.0 * 3600.0, 13.0 * 3600.0);
        assert!((same - 300.0).abs() < 1e-9);
    }

    #[test]
    fn config_json_roundtrip_and_defaults() {
        let cfg = RecoveryConfig { max_spawn_retries: 7, ..Default::default() };
        let back = RecoveryConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);
        // Empty object → all defaults.
        let d = RecoveryConfig::from_json(&Json::obj(vec![])).unwrap();
        assert_eq!(d, RecoveryConfig::default());
        // Out-of-range fallback action rejected.
        let bad = Json::obj(vec![("fallback_action", 99u64.into())]);
        assert!(RecoveryConfig::from_json(&bad).is_err());
    }
}
