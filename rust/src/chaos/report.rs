//! Degraded-mode accounting: what actually happened under the plan.
//!
//! [`ChaosCounters`] lives inside the per-function
//! [`crate::simulator::metrics::SimMetrics`] partials and merges through
//! the same ascending-id fold, so counts are shard-count-invariant.
//! [`ChaosReport`] packages the counters with driver-side and plan-derived
//! quantities and renders the `CHAOS_SUMMARY` line the tooling
//! (`scripts/bench_smoke.sh`) parses.

use crate::chaos::plan::FaultPlan;
use crate::util::json::Json;

/// Event counts accumulated on the decision path. Plain sums on merge.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ChaosCounters {
    /// Failed pod-spawn attempts that were retried.
    pub spawn_retries: u64,
    /// Total backoff delay charged to cold starts (seconds).
    pub retry_delay_s: f64,
    /// Keep-alive decisions that timed out to the static fallback action.
    pub degraded_decisions: u64,
    /// Decisions made against the stale-carbon fallback estimate.
    pub stale_ci_decisions: u64,
}

impl ChaosCounters {
    /// Fold another partial in (plain sums; call in ascending function-id
    /// order like the rest of the metrics merge).
    pub fn merge(&mut self, other: &ChaosCounters) {
        self.spawn_retries += other.spawn_retries;
        self.retry_delay_s += other.retry_delay_s;
        self.degraded_decisions += other.degraded_decisions;
        self.stale_ci_decisions += other.stale_ci_decisions;
    }

    /// True when any degraded path was taken.
    pub fn any(&self) -> bool {
        self.spawn_retries > 0 || self.degraded_decisions > 0 || self.stale_ci_decisions > 0
    }
}

/// End-of-run resilience report for one serve/simulate under a plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosReport {
    /// Decision-path counters (shard-merged).
    pub counters: ChaosCounters,
    /// Driver stalls actually hit (wall-clock accounting).
    pub driver_stalls: u64,
    /// Seconds the carbon feed was down within the run horizon.
    pub fallback_s: f64,
}

impl ChaosReport {
    /// Assemble the report; `fallback_s` comes from the plan's outage
    /// windows clipped to the run horizon `t_end`.
    pub fn new(counters: ChaosCounters, driver_stalls: u64, plan: &FaultPlan, t_end: f64) -> Self {
        ChaosReport { counters, driver_stalls, fallback_s: plan.outage_seconds(t_end) }
    }

    /// Total fault events injected across all classes.
    pub fn faults_injected(&self) -> u64 {
        self.counters.spawn_retries
            + self.counters.degraded_decisions
            + self.counters.stale_ci_decisions
            + self.driver_stalls
    }

    /// JSON form (one `chaos` line in the serve obs stream).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("faults_injected", self.faults_injected().into()),
            ("spawn_retries", self.counters.spawn_retries.into()),
            ("retry_delay_s", self.counters.retry_delay_s.into()),
            ("degraded_decisions", self.counters.degraded_decisions.into()),
            ("stale_ci_decisions", self.counters.stale_ci_decisions.into()),
            ("driver_stalls", self.driver_stalls.into()),
            ("fallback_s", self.fallback_s.into()),
        ])
    }

    /// The greppable one-liner (`CHAOS_SUMMARY {json}`) printed after a
    /// serve report when a plan is installed.
    pub fn summary_line(&self) -> String {
        format!("CHAOS_SUMMARY {}", self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_is_plain_sums() {
        let mut a = ChaosCounters {
            spawn_retries: 1,
            retry_delay_s: 0.5,
            degraded_decisions: 2,
            stale_ci_decisions: 3,
        };
        let b = ChaosCounters {
            spawn_retries: 10,
            retry_delay_s: 1.5,
            degraded_decisions: 20,
            stale_ci_decisions: 30,
        };
        a.merge(&b);
        assert_eq!(a.spawn_retries, 11);
        assert_eq!(a.retry_delay_s, 2.0);
        assert_eq!(a.degraded_decisions, 22);
        assert_eq!(a.stale_ci_decisions, 33);
        assert!(a.any());
        assert!(!ChaosCounters::default().any());
    }

    #[test]
    fn summary_line_is_parseable_and_complete() {
        let plan = FaultPlan::canned(1, 0.0, 1000.0, 1.0);
        let report = ChaosReport::new(
            ChaosCounters { spawn_retries: 4, retry_delay_s: 2.0, ..Default::default() },
            1,
            &plan,
            1000.0,
        );
        let line = report.summary_line();
        let json = line.strip_prefix("CHAOS_SUMMARY ").unwrap();
        let j = Json::parse(json).unwrap();
        for key in [
            "faults_injected",
            "spawn_retries",
            "retry_delay_s",
            "degraded_decisions",
            "stale_ci_decisions",
            "driver_stalls",
            "fallback_s",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.get("faults_injected").and_then(Json::as_usize), Some(5));
        assert!(j.get("fallback_s").and_then(Json::as_f64).unwrap() > 0.0);
    }
}
