//! Workload driver (paper §III-A component 1): streams trace invocations
//! into the router's request channel.
//!
//! Supports max-speed replay (throughput measurement) and paced replay at a
//! configurable time acceleration (latency realism). Runs on its own
//! thread; the channel provides natural backpressure.

use std::sync::mpsc::SyncSender;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::router::InvocationRequest;
use crate::trace::model::Trace;

/// Replay pacing.
#[derive(Debug, Clone, Copy)]
pub enum Pace {
    /// Send as fast as the channel accepts.
    MaxSpeed,
    /// Replay virtual time scaled by `speedup` (e.g. 60 = 1 min/s).
    RealTime { speedup: f64 },
}

/// Stream `trace` into `tx` on a new thread. Returns the join handle; the
/// channel is closed when the trace ends.
pub fn spawn_driver(
    trace: &Trace,
    pace: Pace,
    tx: SyncSender<InvocationRequest>,
) -> JoinHandle<u64> {
    let invocations: Vec<(f64, u32, f64)> = trace
        .invocations
        .iter()
        .map(|i| (i.t, i.func, i.exec_s))
        .collect();
    std::thread::spawn(move || {
        let start = Instant::now();
        let t0 = invocations.first().map(|x| x.0).unwrap_or(0.0);
        let mut sent = 0u64;
        for (id, (t, func, exec_s)) in invocations.into_iter().enumerate() {
            if let Pace::RealTime { speedup } = pace {
                let target = Duration::from_secs_f64(((t - t0) / speedup).max(0.0));
                let elapsed = start.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
            }
            if tx
                .send(InvocationRequest { id: id as u64, t, func, exec_s })
                .is_err()
            {
                break; // router gone
            }
            sent += 1;
        }
        sent
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::model::{FunctionProfile, Invocation, Runtime, TriggerType};
    use std::sync::mpsc::sync_channel;

    fn trace(n: usize) -> Trace {
        Trace::new(
            vec![FunctionProfile {
                id: 0,
                runtime: Runtime::Python,
                trigger: TriggerType::Http,
                mem_mb: 64.0,
                cpu_cores: 1.0,
                cold_start_s: 0.1,
                mean_exec_s: 0.1,
            }],
            (0..n)
                .map(|i| Invocation { t: i as f64 * 0.1, func: 0, exec_s: 0.01 })
                .collect(),
        )
    }

    #[test]
    fn max_speed_delivers_all_in_order() {
        let t = trace(100);
        let (tx, rx) = sync_channel(8);
        let h = spawn_driver(&t, Pace::MaxSpeed, tx);
        let got: Vec<InvocationRequest> = rx.iter().collect();
        assert_eq!(h.join().unwrap(), 100);
        assert_eq!(got.len(), 100);
        assert!(got.windows(2).all(|w| w[0].t <= w[1].t));
        assert_eq!(got[0].id, 0);
        assert_eq!(got[99].id, 99);
    }

    #[test]
    fn stops_when_receiver_dropped() {
        let t = trace(10_000);
        let (tx, rx) = sync_channel(1);
        let h = spawn_driver(&t, Pace::MaxSpeed, tx);
        // Take 5 then hang up.
        let taken: Vec<_> = rx.iter().take(5).collect();
        drop(rx);
        assert_eq!(taken.len(), 5);
        let sent = h.join().unwrap();
        assert!(sent < 10_000);
    }

    #[test]
    fn paced_replay_respects_time() {
        let t = trace(5); // spans 0.4 virtual seconds
        let (tx, rx) = sync_channel(16);
        let start = Instant::now();
        let h = spawn_driver(&t, Pace::RealTime { speedup: 4.0 }, tx);
        let _: Vec<_> = rx.iter().collect();
        h.join().unwrap();
        // 0.4s / 4x = 0.1s minimum wall time.
        assert!(start.elapsed() >= Duration::from_millis(90));
    }
}
