//! Workload driver (paper §III-A component 1): streams trace invocations
//! into the router's request channel.
//!
//! Supports max-speed replay (throughput measurement) and paced replay at a
//! configurable time acceleration (latency realism). Runs on its own
//! thread; the channel provides natural backpressure.
//!
//! With a [`ChaosInjector`] attached ([`spawn_driver_chaos`]), the driver
//! honors the plan's stall windows: replay pauses wall-clock time when
//! virtual time crosses a stall, without perturbing the virtual timestamps
//! delivered downstream — so injected stalls never change the simulated
//! outcome, only the wall-clock envelope (and the degraded-mode counters).

use std::sync::mpsc::SyncSender;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::chaos::ChaosInjector;
use crate::coordinator::router::InvocationRequest;
use crate::trace::model::Trace;

/// Replay pacing.
#[derive(Debug, Clone, Copy)]
pub enum Pace {
    /// Send as fast as the channel accepts.
    MaxSpeed,
    /// Replay virtual time scaled by `speedup` (e.g. 60 = 1 min/s).
    RealTime { speedup: f64 },
}

/// Longest wall-clock pause a single injected stall may impose (seconds);
/// keeps a corrupt plan from wedging the driver thread.
const MAX_STALL_SLEEP_S: f64 = 5.0;

/// Stream `trace` into `tx` on a new thread. Returns the join handle; the
/// channel is closed when the trace ends.
pub fn spawn_driver(
    trace: &Trace,
    pace: Pace,
    tx: SyncSender<InvocationRequest>,
) -> JoinHandle<u64> {
    spawn_driver_chaos(trace, pace, tx, None)
}

/// [`spawn_driver`] with an optional fault injector for stall windows.
///
/// A non-finite or non-positive `RealTime` speedup would turn the sleep
/// targets into NaN or infinity (a NaN `(t - t0) / speedup` survives the
/// `.max(0.0)` clamp because NaN comparisons are false, and `speedup = 0`
/// yields infinite targets); such values fall back to max-speed replay
/// with a warning instead.
pub fn spawn_driver_chaos(
    trace: &Trace,
    pace: Pace,
    tx: SyncSender<InvocationRequest>,
    chaos: Option<Arc<ChaosInjector>>,
) -> JoinHandle<u64> {
    let pace = match pace {
        Pace::RealTime { speedup } if !(speedup.is_finite() && speedup > 0.0) => {
            eprintln!(
                "[driver] invalid replay speedup {speedup}; falling back to max-speed"
            );
            Pace::MaxSpeed
        }
        p => p,
    };
    let invocations: Vec<(f64, u32, f64)> = trace
        .invocations
        .iter()
        .map(|i| (i.t, i.func, i.exec_s))
        .collect();
    let stalls: Vec<(f64, f64)> = chaos
        .as_deref()
        .map(|ch| ch.stall_windows().to_vec())
        .unwrap_or_default();
    std::thread::spawn(move || {
        let start = Instant::now();
        let t0 = invocations.first().map(|x| x.0).unwrap_or(0.0);
        let mut sent = 0u64;
        let mut si = 0usize; // next stall to trigger (sorted by time)
        for (id, (t, func, exec_s)) in invocations.into_iter().enumerate() {
            while si < stalls.len() && t >= stalls[si].0 {
                if let Some(ch) = chaos.as_deref() {
                    ch.note_stall();
                }
                if let Pace::RealTime { .. } = pace {
                    let dur = stalls[si].1.clamp(0.0, MAX_STALL_SLEEP_S);
                    std::thread::sleep(Duration::from_secs_f64(dur));
                }
                si += 1;
            }
            if let Pace::RealTime { speedup } = pace {
                let target = Duration::from_secs_f64(((t - t0) / speedup).max(0.0));
                let elapsed = start.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
            }
            if tx
                .send(InvocationRequest { id: id as u64, t, func, exec_s })
                .is_err()
            {
                break; // router gone
            }
            sent += 1;
        }
        sent
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::model::{FunctionProfile, Invocation, Runtime, TriggerType};
    use std::sync::mpsc::sync_channel;

    fn trace(n: usize) -> Trace {
        Trace::new(
            vec![FunctionProfile {
                id: 0,
                runtime: Runtime::Python,
                trigger: TriggerType::Http,
                mem_mb: 64.0,
                cpu_cores: 1.0,
                cold_start_s: 0.1,
                mean_exec_s: 0.1,
            }],
            (0..n)
                .map(|i| Invocation { t: i as f64 * 0.1, func: 0, exec_s: 0.01 })
                .collect(),
        )
    }

    #[test]
    fn max_speed_delivers_all_in_order() {
        let t = trace(100);
        let (tx, rx) = sync_channel(8);
        let h = spawn_driver(&t, Pace::MaxSpeed, tx);
        let got: Vec<InvocationRequest> = rx.iter().collect();
        assert_eq!(h.join().unwrap(), 100);
        assert_eq!(got.len(), 100);
        assert!(got.windows(2).all(|w| w[0].t <= w[1].t));
        assert_eq!(got[0].id, 0);
        assert_eq!(got[99].id, 99);
    }

    #[test]
    fn stops_when_receiver_dropped() {
        let t = trace(10_000);
        let (tx, rx) = sync_channel(1);
        let h = spawn_driver(&t, Pace::MaxSpeed, tx);
        // Take 5 then hang up.
        let taken: Vec<_> = rx.iter().take(5).collect();
        drop(rx);
        assert_eq!(taken.len(), 5);
        let sent = h.join().unwrap();
        assert!(sent < 10_000);
    }

    #[test]
    fn paced_replay_respects_time() {
        let t = trace(5); // spans 0.4 virtual seconds
        let (tx, rx) = sync_channel(16);
        let start = Instant::now();
        let h = spawn_driver(&t, Pace::RealTime { speedup: 4.0 }, tx);
        let _: Vec<_> = rx.iter().collect();
        h.join().unwrap();
        // 0.4s / 4x = 0.1s minimum wall time.
        assert!(start.elapsed() >= Duration::from_millis(90));
    }

    #[test]
    fn invalid_speedups_fall_back_to_max_speed() {
        // NaN and zero speedups used to produce NaN / infinite sleep
        // targets; both must now deliver the whole trace promptly.
        for speedup in [f64::NAN, 0.0, -3.0, f64::INFINITY] {
            let t = trace(50);
            let (tx, rx) = sync_channel(64);
            let start = Instant::now();
            let h = spawn_driver(&t, Pace::RealTime { speedup }, tx);
            let got: Vec<_> = rx.iter().collect();
            assert_eq!(h.join().unwrap(), 50, "speedup {speedup}");
            assert_eq!(got.len(), 50);
            assert!(start.elapsed() < Duration::from_secs(2), "speedup {speedup}");
        }
    }

    #[test]
    fn stall_windows_counted_without_perturbing_timestamps() {
        use crate::chaos::{ChaosInjector, Fault, FaultPlan, RecoveryConfig};
        let plan = FaultPlan {
            seed: 3,
            faults: vec![
                Fault::DriverStall { at_s: 0.15, dur_s: 0.01 },
                Fault::DriverStall { at_s: 0.35, dur_s: 0.01 },
            ],
            recovery: RecoveryConfig::default(),
        };
        let inj = Arc::new(ChaosInjector::new(plan));
        let t = trace(10);
        let (tx, rx) = sync_channel(16);
        let h = spawn_driver_chaos(&t, Pace::MaxSpeed, tx, Some(inj.clone()));
        let got: Vec<InvocationRequest> = rx.iter().collect();
        assert_eq!(h.join().unwrap(), 10);
        assert_eq!(inj.stalls_hit(), 2);
        // Virtual timestamps are untouched by the stalls.
        for (i, req) in got.iter().enumerate() {
            assert!((req.t - i as f64 * 0.1).abs() < 1e-12);
        }
    }
}
