//! Online pod lifecycle manager: the router's warm-pool state.
//!
//! The same semantics as the simulator's pools (MRU selection, lazy
//! expiry) but organized for incremental online use with out-of-order
//! queries per function.

use crate::simulator::pod::Pod;

/// Result of a pool query for an arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartKind {
    Warm,
    Cold,
}

/// Per-function warm pools with lazy expiry.
#[derive(Debug, Default)]
pub struct PodManager {
    pools: Vec<Vec<Pod>>,
    /// Pods expired since the last drain (idle_start, warm_until, func).
    expired: Vec<(u32, f64, f64)>,
}

impl PodManager {
    pub fn new(n_functions: usize) -> Self {
        PodManager { pools: vec![Vec::new(); n_functions], expired: Vec::new() }
    }

    fn ensure(&mut self, func: u32) {
        let need = func as usize + 1;
        if self.pools.len() < need {
            self.pools.resize_with(need, Vec::new);
        }
    }

    /// Serve an arrival at time `t`: returns Warm (and closes that pod's
    /// idle period, reported via `on_idle_span`) or Cold (allocating a new
    /// pod busy until `completion`). Expired pods are collected for the
    /// caller to account (`drain_expired`).
    pub fn acquire(
        &mut self,
        func: u32,
        t: f64,
        completion: f64,
        mut on_idle_span: impl FnMut(f64, f64),
    ) -> (StartKind, usize) {
        self.ensure(func);
        let pool = &mut self.pools[func as usize];

        // Lazy expiry.
        let mut i = 0;
        while i < pool.len() {
            if pool[i].expired(t) {
                let pod = pool.swap_remove(i);
                self.expired.push((func, pod.idle_start, pod.warm_until));
            } else {
                i += 1;
            }
        }

        // MRU warm pod.
        let mut chosen: Option<usize> = None;
        let mut best = f64::NEG_INFINITY;
        for (pi, pod) in pool.iter().enumerate() {
            if pod.available(t) && pod.idle_start > best {
                best = pod.idle_start;
                chosen = Some(pi);
            }
        }

        match chosen {
            Some(pi) => {
                let pod = &mut pool[pi];
                on_idle_span(pod.idle_start, t);
                pod.busy_until = completion;
                pod.pending = None;
                (StartKind::Warm, pi)
            }
            None => {
                pool.push(Pod::new_busy(completion));
                (StartKind::Cold, pool.len() - 1)
            }
        }
    }

    /// Apply a keep-alive decision for a pod completing at `completion`.
    /// With `refresh = false` (static policies), the window armed at the
    /// pod's first idle period is left untouched on reuse.
    pub fn retain(&mut self, func: u32, pod_idx: usize, completion: f64, keepalive_s: f64) {
        self.retain_with(func, pod_idx, completion, keepalive_s, true)
    }

    pub fn retain_with(
        &mut self,
        func: u32,
        pod_idx: usize,
        completion: f64,
        keepalive_s: f64,
        refresh: bool,
    ) {
        let pod = &mut self.pools[func as usize][pod_idx];
        pod.busy_until = completion;
        pod.idle_start = completion;
        if refresh || pod.warm_until == f64::INFINITY {
            pod.warm_until = completion + keepalive_s;
        }
    }

    /// Take the idle spans of pods that expired since the last call:
    /// `(func, idle_start, warm_until)`.
    pub fn drain_expired(&mut self) -> Vec<(u32, f64, f64)> {
        std::mem::take(&mut self.expired)
    }

    /// Warm pod count for a function (diagnostics).
    pub fn warm_count(&self, func: u32, t: f64) -> usize {
        self.pools
            .get(func as usize)
            .map(|p| p.iter().filter(|pod| pod.available(t)).count())
            .unwrap_or(0)
    }

    /// Total live pods (busy + warm) across all functions.
    pub fn total_pods(&self) -> usize {
        self.pools.iter().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_then_warm_then_expire() {
        let mut pm = PodManager::new(1);
        // Cold at t=0, completes at 1.
        let (k, pi) = pm.acquire(0, 0.0, 1.0, |_, _| {});
        assert_eq!(k, StartKind::Cold);
        pm.retain(0, pi, 1.0, 10.0);
        assert_eq!(pm.warm_count(0, 5.0), 1);

        // Warm reuse at t=5 closes idle span [1, 5].
        let mut spans = Vec::new();
        let (k, pi) = pm.acquire(0, 5.0, 6.0, |a, b| spans.push((a, b)));
        assert_eq!(k, StartKind::Warm);
        assert_eq!(spans, vec![(1.0, 5.0)]);
        pm.retain(0, pi, 6.0, 10.0);

        // t=100: expired, so cold again; expiry drained.
        let (k, _) = pm.acquire(0, 100.0, 101.0, |_, _| {});
        assert_eq!(k, StartKind::Cold);
        let ex = pm.drain_expired();
        assert_eq!(ex, vec![(0, 6.0, 16.0)]);
    }

    #[test]
    fn busy_pod_not_reusable() {
        let mut pm = PodManager::new(1);
        let (_, pi) = pm.acquire(0, 0.0, 10.0, |_, _| {});
        pm.retain(0, pi, 10.0, 60.0);
        // Arrival at t=5 while pod is busy until 10 -> cold.
        let (k, _) = pm.acquire(0, 5.0, 6.0, |_, _| {});
        assert_eq!(k, StartKind::Cold);
        assert_eq!(pm.total_pods(), 2);
    }

    #[test]
    fn mru_selection() {
        let mut pm = PodManager::new(1);
        let (_, p0) = pm.acquire(0, 0.0, 0.5, |_, _| {});
        pm.retain(0, p0, 0.5, 60.0);
        let (k1, p1) = pm.acquire(0, 0.2, 0.7, |_, _| {}); // overlaps -> cold
        assert_eq!(k1, StartKind::Cold);
        pm.retain(0, p1, 0.7, 60.0);
        // Next arrival should pick the more recently idle pod (idle 0.7).
        let mut spans = Vec::new();
        let (k2, _) = pm.acquire(0, 5.0, 6.0, |a, b| spans.push((a, b)));
        assert_eq!(k2, StartKind::Warm);
        assert_eq!(spans, vec![(0.7, 5.0)]);
    }

    #[test]
    fn grows_for_new_functions() {
        let mut pm = PodManager::new(1);
        let (k, _) = pm.acquire(7, 0.0, 1.0, |_, _| {});
        assert_eq!(k, StartKind::Cold);
        assert_eq!(pm.warm_count(7, 0.0), 0);
    }
}
