//! Online pod lifecycle manager: the router's warm-pool state.
//!
//! The same semantics as the simulator's pools (MRU selection, lazy
//! expiry) but organized for incremental online use with out-of-order
//! queries per function. Pending keep-alive decisions ride on the pods
//! ([`Pending`]) so the router can resolve policy outcomes — and attribute
//! a cold start to exactly one tied expiry — with the engine's semantics.

use crate::simulator::pod::{Pending, Pod};

/// Result of a pool query for an arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartKind {
    Warm,
    Cold,
}

/// A pod whose keep-alive window lapsed, drained via
/// [`PodManager::drain_expired`] for the caller to account.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExpiredPod {
    pub func: u32,
    /// Start of the idle period that ended in expiry.
    pub idle_start: f64,
    /// When the keep-alive window lapsed.
    pub warm_until: f64,
    /// The unresolved keep-alive decision, if one was armed.
    pub pending: Option<Pending>,
}

/// Per-function warm pools with lazy expiry.
#[derive(Debug, Default)]
pub struct PodManager {
    pools: Vec<Vec<Pod>>,
    /// Pods expired since the last drain.
    expired: Vec<ExpiredPod>,
}

impl PodManager {
    pub fn new(n_functions: usize) -> Self {
        PodManager { pools: vec![Vec::new(); n_functions], expired: Vec::new() }
    }

    fn ensure(&mut self, func: u32) {
        let need = func as usize + 1;
        if self.pools.len() < need {
            self.pools.resize_with(need, Vec::new);
        }
    }

    /// Serve an arrival at time `t`: returns Warm (and closes that pod's
    /// idle period, reported via `on_idle_span`, handing back its pending
    /// decision for outcome resolution) or Cold (allocating a new pod busy
    /// until `completion`). Expired pods are collected for the caller to
    /// account (`drain_expired`).
    pub fn acquire(
        &mut self,
        func: u32,
        t: f64,
        completion: f64,
        mut on_idle_span: impl FnMut(f64, f64),
    ) -> (StartKind, usize, Option<Pending>) {
        self.ensure(func);
        let pool = &mut self.pools[func as usize];

        // Lazy expiry.
        let mut i = 0;
        while i < pool.len() {
            if pool[i].expired(t) {
                let pod = pool.swap_remove(i);
                self.expired.push(ExpiredPod {
                    func,
                    idle_start: pod.idle_start,
                    warm_until: pod.warm_until,
                    pending: pod.pending,
                });
            } else {
                i += 1;
            }
        }

        // MRU warm pod.
        let mut chosen: Option<usize> = None;
        let mut best = f64::NEG_INFINITY;
        for (pi, pod) in pool.iter().enumerate() {
            if pod.available(t) && pod.idle_start > best {
                best = pod.idle_start;
                chosen = Some(pi);
            }
        }

        match chosen {
            Some(pi) => {
                let pod = &mut pool[pi];
                on_idle_span(pod.idle_start, t);
                pod.busy_until = completion;
                (StartKind::Warm, pi, pod.pending.take())
            }
            None => {
                pool.push(Pod::new_busy(completion));
                (StartKind::Cold, pool.len() - 1, None)
            }
        }
    }

    /// Apply a keep-alive decision for a pod completing at `completion`,
    /// refreshing the window and recording the nearest-grid action as
    /// pending. With out-of-grid timeouts prefer [`PodManager::retain_with`]
    /// and pass the policy's own action index.
    pub fn retain(&mut self, func: u32, pod_idx: usize, completion: f64, keepalive_s: f64) {
        let action = crate::KEEP_ALIVE_ACTIONS
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                (*a - keepalive_s).abs().total_cmp(&(*b - keepalive_s).abs())
            })
            .map(|(i, _)| i)
            .unwrap_or(0);
        self.retain_with(func, pod_idx, completion, keepalive_s, true, action)
    }

    /// Apply a keep-alive decision. With `refresh = false` (static
    /// policies), the window armed at the pod's first idle period is left
    /// untouched on reuse. `action` is the decision's index into
    /// [`crate::KEEP_ALIVE_ACTIONS`], armed as the pod's pending outcome.
    pub fn retain_with(
        &mut self,
        func: u32,
        pod_idx: usize,
        completion: f64,
        keepalive_s: f64,
        refresh: bool,
        action: usize,
    ) {
        let pod = &mut self.pools[func as usize][pod_idx];
        pod.busy_until = completion;
        pod.idle_start = completion;
        if refresh || pod.warm_until == f64::INFINITY {
            pod.warm_until = completion + keepalive_s;
        }
        pod.pending = Some(Pending { action, t: completion });
    }

    /// Take the pods that expired since the last call.
    pub fn drain_expired(&mut self) -> Vec<ExpiredPod> {
        std::mem::take(&mut self.expired)
    }

    /// Warm pod count for a function (diagnostics).
    pub fn warm_count(&self, func: u32, t: f64) -> usize {
        self.pools
            .get(func as usize)
            .map(|p| p.iter().filter(|pod| pod.available(t)).count())
            .unwrap_or(0)
    }

    /// Total live pods (busy + warm) across all functions.
    pub fn total_pods(&self) -> usize {
        self.pools.iter().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_then_warm_then_expire() {
        let mut pm = PodManager::new(1);
        // Cold at t=0, completes at 1.
        let (k, pi, pending) = pm.acquire(0, 0.0, 1.0, |_, _| {});
        assert_eq!(k, StartKind::Cold);
        assert!(pending.is_none());
        pm.retain(0, pi, 1.0, 10.0);
        assert_eq!(pm.warm_count(0, 5.0), 1);

        // Warm reuse at t=5 closes idle span [1, 5] and yields the pending
        // decision armed at completion 1 (10 s keep-alive = action 2).
        let mut spans = Vec::new();
        let (k, pi, pending) = pm.acquire(0, 5.0, 6.0, |a, b| spans.push((a, b)));
        assert_eq!(k, StartKind::Warm);
        assert_eq!(spans, vec![(1.0, 5.0)]);
        assert_eq!(pending, Some(Pending { action: 2, t: 1.0 }));
        pm.retain(0, pi, 6.0, 10.0);

        // t=100: expired, so cold again; expiry drained with its pending.
        let (k, _, _) = pm.acquire(0, 100.0, 101.0, |_, _| {});
        assert_eq!(k, StartKind::Cold);
        let ex = pm.drain_expired();
        assert_eq!(
            ex,
            vec![ExpiredPod {
                func: 0,
                idle_start: 6.0,
                warm_until: 16.0,
                pending: Some(Pending { action: 2, t: 6.0 }),
            }]
        );
    }

    #[test]
    fn busy_pod_not_reusable() {
        let mut pm = PodManager::new(1);
        let (_, pi, _) = pm.acquire(0, 0.0, 10.0, |_, _| {});
        pm.retain(0, pi, 10.0, 60.0);
        // Arrival at t=5 while pod is busy until 10 -> cold.
        let (k, _, _) = pm.acquire(0, 5.0, 6.0, |_, _| {});
        assert_eq!(k, StartKind::Cold);
        assert_eq!(pm.total_pods(), 2);
    }

    #[test]
    fn mru_selection() {
        let mut pm = PodManager::new(1);
        let (_, p0, _) = pm.acquire(0, 0.0, 0.5, |_, _| {});
        pm.retain(0, p0, 0.5, 60.0);
        let (k1, p1, _) = pm.acquire(0, 0.2, 0.7, |_, _| {}); // overlaps -> cold
        assert_eq!(k1, StartKind::Cold);
        pm.retain(0, p1, 0.7, 60.0);
        // Next arrival should pick the more recently idle pod (idle 0.7).
        let mut spans = Vec::new();
        let (k2, _, _) = pm.acquire(0, 5.0, 6.0, |a, b| spans.push((a, b)));
        assert_eq!(k2, StartKind::Warm);
        assert_eq!(spans, vec![(0.7, 5.0)]);
    }

    #[test]
    fn tied_expiries_both_drained_with_pendings() {
        // Two pods with identical warm_until must both drain — attribution
        // (charging exactly one) is the router's job; the pool must not
        // lose either pending decision.
        let mut pm = PodManager::new(1);
        let (_, p0, _) = pm.acquire(0, 0.0, 0.1, |_, _| {});
        let (_, p1, _) = pm.acquire(0, 0.0, 0.1, |_, _| {});
        pm.retain_with(0, p0, 0.1, 1.0, true, 0);
        pm.retain_with(0, p1, 0.1, 1.0, true, 0);
        let (k, _, _) = pm.acquire(0, 100.0, 101.0, |_, _| {});
        assert_eq!(k, StartKind::Cold);
        let ex = pm.drain_expired();
        assert_eq!(ex.len(), 2);
        assert!(ex.iter().all(|x| x.warm_until == 1.1 && x.pending.is_some()));
    }

    #[test]
    fn grows_for_new_functions() {
        let mut pm = PodManager::new(1);
        let (k, _, _) = pm.acquire(7, 0.0, 1.0, |_, _| {});
        assert_eq!(k, StartKind::Cold);
        assert_eq!(pm.warm_count(7, 0.0), 0);
    }
}
