//! Online coordinator: the deployable control plane (paper §III-A,
//! component 4 "Real System").
//!
//! Architecture mirrors a FaaS platform's keep-alive controller sitting
//! *beneath* cluster autoscaling: a workload [`driver`] streams invocation
//! requests over a channel into the [`router`], which owns the per-function
//! warm pools, consults the keep-alive policy at each completion, and
//! answers with the latency outcome. Decision-making is asynchronous to the
//! response path, matching the paper's observation that control-plane
//! enforcement (CRD updates) is off the function's critical path.
//!
//! tokio is unavailable in this environment's offline crate set, so the
//! event loop is `std::thread` + `mpsc` — same topology, no async runtime
//! (DESIGN.md §3).

pub mod driver;
pub mod lifecycle;
pub mod router;
pub mod server;

pub use router::{InvocationRequest, InvocationResponse, Router, RouterConfig};
pub use server::{CoordinatorServer, ServeReport};
