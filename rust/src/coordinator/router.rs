//! The router: online request handling + keep-alive control.
//!
//! Receives [`InvocationRequest`]s (from the driver or any producer),
//! resolves warm/cold against the [`PodManager`], answers with the latency
//! outcome, and applies the policy's keep-alive decision. Timing of the
//! *decision* itself is measured per request — the paper's §IV-E inference
//! overhead, observed in situ.

use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use crate::carbon::intensity::CarbonTrace;
use crate::energy::model::EnergyModel;
use crate::coordinator::lifecycle::{PodManager, StartKind};
use crate::policy::{DecisionContext, KeepAlivePolicy};
use crate::simulator::reuse::ReuseWindow;
use crate::trace::model::FunctionProfile;
use crate::util::stats::Running;

/// One invocation submitted to the control plane. `t` is virtual workload
/// time (seconds); the router is clock-agnostic so drivers can replay
/// traces at any acceleration.
#[derive(Debug, Clone)]
pub struct InvocationRequest {
    pub id: u64,
    pub t: f64,
    pub func: u32,
    pub exec_s: f64,
}

/// The router's answer.
#[derive(Debug, Clone)]
pub struct InvocationResponse {
    pub id: u64,
    pub cold: bool,
    /// End-to-end latency (cold + exec + network), virtual seconds.
    pub latency_s: f64,
    /// Keep-alive chosen for the pod (seconds).
    pub keepalive_s: f64,
    /// Wall-clock cost of the policy decision (ns) — §IV-E.
    pub decision_ns: u64,
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub lambda_carbon: f64,
    pub network_latency_s: f64,
    pub reuse_window: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            lambda_carbon: 0.5,
            network_latency_s: crate::NETWORK_LATENCY_S,
            reuse_window: crate::simulator::reuse::DEFAULT_WINDOW,
        }
    }
}

/// Router metrics, mirroring the simulator's where applicable.
#[derive(Debug, Clone, Default)]
pub struct RouterMetrics {
    pub requests: u64,
    pub cold_starts: u64,
    pub latency: Running,
    pub decision_ns: Running,
    pub keepalive_carbon_g: f64,
}

/// The router. Single-owner state machine: wrap it in a thread with an
/// mpsc receiver ([`Router::serve`]) or drive it synchronously
/// ([`Router::handle`]) from tests and benches.
pub struct Router<P: KeepAlivePolicy> {
    functions: Vec<FunctionProfile>,
    policy: P,
    pods: PodManager,
    windows: Vec<ReuseWindow>,
    last_completion: Vec<f64>,
    ci: CarbonTrace,
    energy: EnergyModel,
    cfg: RouterConfig,
    pub metrics: RouterMetrics,
}

impl<P: KeepAlivePolicy> Router<P> {
    pub fn new(
        functions: Vec<FunctionProfile>,
        policy: P,
        ci: CarbonTrace,
        energy: EnergyModel,
        cfg: RouterConfig,
    ) -> Self {
        let n = functions.len();
        let windows = (0..n).map(|_| ReuseWindow::new(cfg.reuse_window)).collect();
        Router {
            functions,
            policy,
            pods: PodManager::new(n),
            windows,
            last_completion: vec![f64::NEG_INFINITY; n],
            ci,
            energy,
            cfg,
            metrics: RouterMetrics::default(),
        }
    }

    pub fn policy_mut(&mut self) -> &mut P {
        &mut self.policy
    }

    /// Consume the router, returning the policy and final metrics.
    pub fn into_parts(self) -> (P, RouterMetrics) {
        (self.policy, self.metrics)
    }

    /// Handle one request synchronously.
    pub fn handle(&mut self, req: &InvocationRequest) -> InvocationResponse {
        let f = req.func as usize;
        let prof = &self.functions[f];
        let idle_w = self.energy.lambda_idle
            * self.energy.active_power_w(prof.mem_mb, prof.cpu_cores);

        // Reuse window update.
        if self.last_completion[f] > f64::NEG_INFINITY {
            self.windows[f].push((req.t - self.last_completion[f]).max(0.0));
        }

        // Serve (idle spans closed by reuse are carbon-accounted here).
        let mut idle_carbon = 0.0;
        let ci = &self.ci;
        let energy_per_kwh = crate::energy::JOULES_PER_KWH;
        let cold_first_guess = req.t + prof.cold_start_s + req.exec_s;
        let (kind, pod_idx) = self.pods.acquire(req.func, req.t, cold_first_guess, |a, b| {
            idle_carbon += idle_w * ci.integrate(a, b) / energy_per_kwh;
        });
        // Expired pods accrue their full idle span.
        for (xf, a, b) in self.pods.drain_expired() {
            let xprof = &self.functions[xf as usize];
            let xw = self.energy.lambda_idle
                * self.energy.active_power_w(xprof.mem_mb, xprof.cpu_cores);
            idle_carbon += xw * ci.integrate(a, b) / energy_per_kwh;
        }
        self.metrics.keepalive_carbon_g += idle_carbon;

        let (cold, cold_lat) = match kind {
            StartKind::Warm => (false, 0.0),
            StartKind::Cold => (true, prof.cold_start_s),
        };
        let completion = req.t + cold_lat + req.exec_s;

        // Keep-alive decision (timed — this is the §IV-E overhead).
        let ctx = DecisionContext {
            t: completion,
            func: prof,
            ci: self.ci.at(completion),
            reuse_probs: self.windows[f].probs(),
            lambda_carbon: self.cfg.lambda_carbon,
            idle_power_w: idle_w,
            next_arrival_gap: None,
        };
        let t0 = Instant::now();
        let (_action, keepalive_s) = self.policy.decide_seconds(&ctx);
        let decision_ns = t0.elapsed().as_nanos() as u64;
        self.pods.retain_with(
            req.func,
            pod_idx,
            completion,
            keepalive_s,
            self.policy.refreshes_timer(),
        );
        self.last_completion[f] = completion;

        let latency_s = cold_lat + req.exec_s + self.cfg.network_latency_s;
        self.metrics.requests += 1;
        if cold {
            self.metrics.cold_starts += 1;
        }
        self.metrics.latency.add(latency_s);
        self.metrics.decision_ns.add(decision_ns as f64);

        InvocationResponse { id: req.id, cold, latency_s, keepalive_s, decision_ns }
    }

    /// Serve until the request channel closes, replying on `out`.
    pub fn serve(
        mut self,
        requests: Receiver<InvocationRequest>,
        out: Sender<InvocationResponse>,
    ) -> Self {
        while let Ok(req) = requests.recv() {
            let resp = self.handle(&req);
            if out.send(resp).is_err() {
                break; // consumer gone
            }
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::fixed::FixedTimeout;
    use crate::trace::model::{Runtime, TriggerType};

    fn profile(id: u32) -> FunctionProfile {
        FunctionProfile {
            id,
            runtime: Runtime::Python,
            trigger: TriggerType::Http,
            mem_mb: 64.0,
            cpu_cores: 1.0,
            cold_start_s: 0.4,
            mean_exec_s: 0.1,
        }
    }

    fn router() -> Router<FixedTimeout> {
        Router::new(
            vec![profile(0)],
            FixedTimeout::huawei(),
            CarbonTrace::constant(300.0),
            EnergyModel::default(),
            RouterConfig::default(),
        )
    }

    #[test]
    fn cold_then_warm() {
        let mut r = router();
        let a = r.handle(&InvocationRequest { id: 1, t: 0.0, func: 0, exec_s: 0.1 });
        assert!(a.cold);
        assert!((a.latency_s - (0.4 + 0.1 + crate::NETWORK_LATENCY_S)).abs() < 1e-12);
        let b = r.handle(&InvocationRequest { id: 2, t: 5.0, func: 0, exec_s: 0.1 });
        assert!(!b.cold);
        assert_eq!(b.keepalive_s, 60.0);
        assert_eq!(r.metrics.cold_starts, 1);
        assert_eq!(r.metrics.requests, 2);
        assert!(r.metrics.keepalive_carbon_g > 0.0);
    }

    #[test]
    fn expiry_goes_cold_again() {
        let mut r = router();
        r.handle(&InvocationRequest { id: 1, t: 0.0, func: 0, exec_s: 0.1 });
        let b = r.handle(&InvocationRequest { id: 2, t: 500.0, func: 0, exec_s: 0.1 });
        assert!(b.cold);
    }

    #[test]
    fn decision_time_measured() {
        let mut r = router();
        let a = r.handle(&InvocationRequest { id: 1, t: 0.0, func: 0, exec_s: 0.1 });
        // Sub-millisecond for a fixed policy.
        assert!(a.decision_ns < 1_000_000);
    }

    #[test]
    fn threaded_serve_roundtrip() {
        use std::sync::mpsc::channel;
        let r = router();
        let (req_tx, req_rx) = channel();
        let (resp_tx, resp_rx) = channel();
        let handle = std::thread::spawn(move || r.serve(req_rx, resp_tx));
        for i in 0..10u64 {
            req_tx
                .send(InvocationRequest { id: i, t: i as f64, func: 0, exec_s: 0.05 })
                .unwrap();
        }
        drop(req_tx);
        let resps: Vec<InvocationResponse> = resp_rx.iter().collect();
        assert_eq!(resps.len(), 10);
        assert!(resps[0].cold);
        assert!(resps.iter().skip(1).all(|r| !r.cold));
        let r = handle.join().unwrap();
        assert_eq!(r.metrics.requests, 10);
    }
}
