//! The router: online request handling + keep-alive control.
//!
//! Receives [`InvocationRequest`]s (from the driver or any producer),
//! resolves warm/cold against the [`PodManager`], answers with the latency
//! outcome, and applies the policy's keep-alive decision. Timing of the
//! *decision* itself is measured per request — the paper's §IV-E inference
//! overhead, observed in situ.
//!
//! Realized decision outcomes are reported through
//! [`KeepAlivePolicy::observe`] with the engine's exact semantics — a cold
//! start is attributed to exactly one expired decision, ties on
//! `warm_until` charging the last drained — so the online path is
//! bit-identical to `simulator::engine` on the same trace + policy
//! (property-tested in `rust/tests/property_lifecycle.rs`).
//!
//! When a [`crate::chaos::ChaosInjector`] is installed via
//! [`RouterConfig::chaos`], the same injection points as the engine apply:
//! spawn-failure backoff on cold starts, stale-carbon fallback at decision
//! time, decision-timeout degradation to the static fallback action. With
//! no injector, behavior is byte-identical to a build without the chaos
//! subsystem (`rust/tests/property_chaos.rs`).

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::carbon::intensity::CarbonTrace;
use crate::chaos::{ChaosCounters, ChaosInjector};
use crate::coordinator::lifecycle::{PodManager, StartKind};
use crate::energy::model::EnergyModel;
use crate::policy::{DecisionContext, KeepAlivePolicy, Outcome};
use crate::simulator::reuse::ReuseWindow;
use crate::trace::model::FunctionProfile;
use crate::util::stats::Running;
use crate::KEEP_ALIVE_ACTIONS;

/// One invocation submitted to the control plane. `t` is virtual workload
/// time (seconds); the router is clock-agnostic so drivers can replay
/// traces at any acceleration.
#[derive(Debug, Clone)]
pub struct InvocationRequest {
    pub id: u64,
    pub t: f64,
    pub func: u32,
    pub exec_s: f64,
}

/// The router's answer.
#[derive(Debug, Clone)]
pub struct InvocationResponse {
    pub id: u64,
    pub cold: bool,
    /// End-to-end latency (cold + exec + network), virtual seconds.
    pub latency_s: f64,
    /// Keep-alive chosen for the pod (seconds).
    pub keepalive_s: f64,
    /// Wall-clock cost of the policy decision (ns) — §IV-E.
    pub decision_ns: u64,
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub lambda_carbon: f64,
    pub network_latency_s: f64,
    pub reuse_window: usize,
    /// Fault injector shared with the driver; `None` disables injection
    /// entirely (byte-identical to the pre-chaos serve path).
    pub chaos: Option<Arc<ChaosInjector>>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            lambda_carbon: 0.5,
            network_latency_s: crate::NETWORK_LATENCY_S,
            reuse_window: crate::simulator::reuse::DEFAULT_WINDOW,
            chaos: None,
        }
    }
}

/// Router metrics, mirroring the simulator's where applicable.
#[derive(Debug, Clone, Default)]
pub struct RouterMetrics {
    pub requests: u64,
    pub cold_starts: u64,
    pub latency: Running,
    pub decision_ns: Running,
    pub keepalive_carbon_g: f64,
    /// Degraded-mode event counts (all zero without an injector).
    pub chaos: ChaosCounters,
    /// Latest completion time seen (virtual s) — the horizon for
    /// plan-derived accounting like carbon-outage fallback seconds.
    pub t_end: f64,
}

/// The router. Single-owner state machine: wrap it in a thread with an
/// mpsc receiver ([`Router::serve`]) or drive it synchronously
/// ([`Router::handle`]) from tests and benches.
pub struct Router<P: KeepAlivePolicy> {
    functions: Vec<FunctionProfile>,
    policy: P,
    pods: PodManager,
    windows: Vec<ReuseWindow>,
    last_completion: Vec<f64>,
    ci: CarbonTrace,
    energy: EnergyModel,
    cfg: RouterConfig,
    pub metrics: RouterMetrics,
}

impl<P: KeepAlivePolicy> Router<P> {
    pub fn new(
        functions: Vec<FunctionProfile>,
        policy: P,
        ci: CarbonTrace,
        energy: EnergyModel,
        cfg: RouterConfig,
    ) -> Self {
        let n = functions.len();
        let windows = (0..n).map(|_| ReuseWindow::new(cfg.reuse_window)).collect();
        Router {
            functions,
            policy,
            pods: PodManager::new(n),
            windows,
            last_completion: vec![f64::NEG_INFINITY; n],
            ci,
            energy,
            cfg,
            metrics: RouterMetrics::default(),
        }
    }

    pub fn policy_mut(&mut self) -> &mut P {
        &mut self.policy
    }

    /// Consume the router, returning the policy and final metrics.
    pub fn into_parts(self) -> (P, RouterMetrics) {
        (self.policy, self.metrics)
    }

    /// Handle one request synchronously.
    pub fn handle(&mut self, req: &InvocationRequest) -> InvocationResponse {
        let f = req.func as usize;
        let prof = &self.functions[f];
        let idle_w = self.energy.lambda_idle
            * self.energy.active_power_w(prof.mem_mb, prof.cpu_cores);

        // Reuse window update.
        if self.last_completion[f] > f64::NEG_INFINITY {
            self.windows[f].push((req.t - self.last_completion[f]).max(0.0));
        }

        // Spawn-failure query: stateless, so it can run before warm/cold
        // is known; the result only applies when the start is cold.
        let (retry_delay, retries) = match self.cfg.chaos.as_deref() {
            Some(ch) => ch.spawn_delay(req.func, req.t),
            None => (0.0, 0),
        };

        // Serve (idle spans closed by reuse are carbon-accounted here).
        let ci = &self.ci;
        let energy_per_kwh = crate::energy::JOULES_PER_KWH;
        let cold_first_guess = if retries > 0 {
            req.t + prof.cold_start_s + retry_delay + req.exec_s
        } else {
            req.t + prof.cold_start_s + req.exec_s
        };
        let mut reuse: Option<(f64, f64)> = None; // (idle_start, idle carbon)
        let (kind, pod_idx, reused_pending) =
            self.pods.acquire(req.func, req.t, cold_first_guess, |a, b| {
                reuse = Some((a, idle_w * ci.integrate(a, b) / energy_per_kwh));
            });
        let mut idle_carbon = reuse.map_or(0.0, |(_, g)| g);
        // Expired pods accrue their full idle span.
        let drained = self.pods.drain_expired();
        for x in &drained {
            let xprof = &self.functions[x.func as usize];
            let xw = self.energy.lambda_idle
                * self.energy.active_power_w(xprof.mem_mb, xprof.cpu_cores);
            idle_carbon += xw * ci.integrate(x.idle_start, x.warm_until) / energy_per_kwh;
        }
        self.metrics.keepalive_carbon_g += idle_carbon;

        let (cold, cold_lat) = match kind {
            StartKind::Warm => (false, 0.0),
            StartKind::Cold => {
                if retries > 0 {
                    self.metrics.chaos.spawn_retries += u64::from(retries);
                    self.metrics.chaos.retry_delay_s += retry_delay;
                    (true, prof.cold_start_s + retry_delay)
                } else {
                    (true, prof.cold_start_s)
                }
            }
        };
        let completion = req.t + cold_lat + req.exec_s;

        // Resolve policy outcomes with the engine's semantics: the reused
        // pod's decision first, then this arrival's expiries. A cold start
        // charges exactly one expired decision — the most recent expiry,
        // ties on `warm_until` going to the last drained.
        if let Some(p) = reused_pending {
            let (idle_start, g) = reuse.unwrap_or((req.t, 0.0));
            self.policy.observe(&Outcome {
                func: req.func,
                action: p.action,
                t: p.t,
                resolved_t: req.t,
                reused: true,
                idle_span_s: req.t - idle_start,
                idle_carbon_g: g,
                cold_penalty_s: 0.0,
                done: false,
            });
        }
        if !drained.is_empty() {
            let mut charged = usize::MAX;
            if cold {
                let mut best = f64::NEG_INFINITY;
                for (ei, x) in drained.iter().enumerate() {
                    if x.pending.is_some() && x.warm_until >= best {
                        best = x.warm_until;
                        charged = ei;
                    }
                }
            }
            for (ei, x) in drained.iter().enumerate() {
                let Some(p) = x.pending else { continue };
                let xprof = &self.functions[x.func as usize];
                let xw = self.energy.lambda_idle
                    * self.energy.active_power_w(xprof.mem_mb, xprof.cpu_cores);
                let g = xw * ci.integrate(x.idle_start, x.warm_until) / energy_per_kwh;
                let penalty = if ei == charged { cold_lat } else { 0.0 };
                self.policy.observe(&Outcome {
                    func: x.func,
                    action: p.action,
                    t: p.t,
                    resolved_t: req.t,
                    reused: false,
                    idle_span_s: (x.warm_until - x.idle_start).max(0.0),
                    idle_carbon_g: g,
                    cold_penalty_s: penalty,
                    done: false,
                });
            }
        }

        // Keep-alive decision (timed — this is the §IV-E overhead). During
        // a carbon-feed outage the decision sees the stale-fallback
        // estimate; accounting above always uses the true trace.
        let ci_now = match self.cfg.chaos.as_deref() {
            Some(ch) => match ch.stale_since(completion) {
                Some(outage_start) => {
                    self.metrics.chaos.stale_ci_decisions += 1;
                    ch.fallback_ci(&self.ci, completion, outage_start)
                }
                None => self.ci.at(completion),
            },
            None => self.ci.at(completion),
        };
        let ctx = DecisionContext {
            t: completion,
            func: prof,
            ci: ci_now,
            reuse_probs: self.windows[f].probs(),
            lambda_carbon: self.cfg.lambda_carbon,
            idle_power_w: idle_w,
            next_arrival_gap: None,
        };
        let t0 = Instant::now();
        let (action, keepalive_s) = self.policy.decide_seconds(&ctx);
        let decision_ns = t0.elapsed().as_nanos() as u64;
        // A decision slower than the recovery timeout is discarded: the
        // static fallback keep-alive applies (the policy still ran, so
        // stateful policies stay consistent with the simulated stack).
        let (action, keepalive_s) = match self.cfg.chaos.as_deref() {
            Some(ch) if ch.decision_degraded(completion) => {
                self.metrics.chaos.degraded_decisions += 1;
                let a = ch.recovery().fallback_action.min(KEEP_ALIVE_ACTIONS.len() - 1);
                (a, KEEP_ALIVE_ACTIONS[a])
            }
            _ => (action.min(KEEP_ALIVE_ACTIONS.len() - 1), keepalive_s),
        };
        self.pods.retain_with(
            req.func,
            pod_idx,
            completion,
            keepalive_s,
            self.policy.refreshes_timer(),
            action,
        );
        self.last_completion[f] = completion;

        let latency_s = cold_lat + req.exec_s + self.cfg.network_latency_s;
        self.metrics.requests += 1;
        if cold {
            self.metrics.cold_starts += 1;
        }
        self.metrics.latency.add(latency_s);
        self.metrics.decision_ns.add(decision_ns as f64);
        if completion > self.metrics.t_end {
            self.metrics.t_end = completion;
        }

        InvocationResponse { id: req.id, cold, latency_s, keepalive_s, decision_ns }
    }

    /// Serve until the request channel closes, replying on `out`.
    pub fn serve(
        mut self,
        requests: Receiver<InvocationRequest>,
        out: Sender<InvocationResponse>,
    ) -> Self {
        while let Ok(req) = requests.recv() {
            let resp = self.handle(&req);
            if out.send(resp).is_err() {
                break; // consumer gone
            }
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::fixed::FixedTimeout;
    use crate::trace::model::{Runtime, TriggerType};

    fn profile(id: u32) -> FunctionProfile {
        FunctionProfile {
            id,
            runtime: Runtime::Python,
            trigger: TriggerType::Http,
            mem_mb: 64.0,
            cpu_cores: 1.0,
            cold_start_s: 0.4,
            mean_exec_s: 0.1,
        }
    }

    fn router() -> Router<FixedTimeout> {
        Router::new(
            vec![profile(0)],
            FixedTimeout::huawei(),
            CarbonTrace::constant(300.0),
            EnergyModel::default(),
            RouterConfig::default(),
        )
    }

    #[test]
    fn cold_then_warm() {
        let mut r = router();
        let a = r.handle(&InvocationRequest { id: 1, t: 0.0, func: 0, exec_s: 0.1 });
        assert!(a.cold);
        assert!((a.latency_s - (0.4 + 0.1 + crate::NETWORK_LATENCY_S)).abs() < 1e-12);
        let b = r.handle(&InvocationRequest { id: 2, t: 5.0, func: 0, exec_s: 0.1 });
        assert!(!b.cold);
        assert_eq!(b.keepalive_s, 60.0);
        assert_eq!(r.metrics.cold_starts, 1);
        assert_eq!(r.metrics.requests, 2);
        assert!(r.metrics.keepalive_carbon_g > 0.0);
        assert!(!r.metrics.chaos.any());
    }

    #[test]
    fn expiry_goes_cold_again() {
        let mut r = router();
        r.handle(&InvocationRequest { id: 1, t: 0.0, func: 0, exec_s: 0.1 });
        let b = r.handle(&InvocationRequest { id: 2, t: 500.0, func: 0, exec_s: 0.1 });
        assert!(b.cold);
    }

    #[test]
    fn decision_time_measured() {
        let mut r = router();
        let a = r.handle(&InvocationRequest { id: 1, t: 0.0, func: 0, exec_s: 0.1 });
        // Sub-millisecond for a fixed policy.
        assert!(a.decision_ns < 1_000_000);
    }

    #[test]
    fn tied_expiries_charge_exactly_one_cold_start() {
        // Mirror of the engine regression: two pods with tied warm_until
        // both expire before a cold arrival; exactly one of their decisions
        // takes the cold penalty (the online path used to have no outcome
        // attribution at all, and a naive port double-charged ties).
        struct Cap(Vec<Outcome>);
        impl KeepAlivePolicy for Cap {
            fn name(&self) -> &str {
                "cap"
            }
            fn decide(&mut self, _: &DecisionContext) -> usize {
                0 // always 1s keep-alive
            }
            fn observe(&mut self, o: &Outcome) {
                self.0.push(*o);
            }
        }
        let mut prof = profile(0);
        prof.cold_start_s = 3.0;
        let mut r = Router::new(
            vec![prof],
            Cap(Vec::new()),
            CarbonTrace::constant(300.0),
            EnergyModel::default(),
            RouterConfig::default(),
        );
        for (id, t) in [(1u64, 0.0), (2, 0.0), (3, 100.0)] {
            r.handle(&InvocationRequest { id, t, func: 0, exec_s: 0.1 });
        }
        let (cap, _) = r.into_parts();
        let expired: Vec<&Outcome> = cap.0.iter().filter(|o| !o.reused).collect();
        assert_eq!(expired.len(), 2);
        let charged: Vec<&&Outcome> =
            expired.iter().filter(|o| o.cold_penalty_s > 0.0).collect();
        assert_eq!(charged.len(), 1, "exactly one tied expiry takes the penalty");
        assert!((charged[0].cold_penalty_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn threaded_serve_roundtrip() {
        use std::sync::mpsc::channel;
        let r = router();
        let (req_tx, req_rx) = channel();
        let (resp_tx, resp_rx) = channel();
        let handle = std::thread::spawn(move || r.serve(req_rx, resp_tx));
        for i in 0..10u64 {
            req_tx
                .send(InvocationRequest { id: i, t: i as f64, func: 0, exec_s: 0.05 })
                .unwrap();
        }
        drop(req_tx);
        let resps: Vec<InvocationResponse> = resp_rx.iter().collect();
        assert_eq!(resps.len(), 10);
        assert!(resps[0].cold);
        assert!(resps.iter().skip(1).all(|r| !r.cold));
        let r = handle.join().unwrap();
        assert_eq!(r.metrics.requests, 10);
    }

    #[test]
    fn spawn_failure_window_delays_cold_starts() {
        use crate::chaos::{ChaosInjector, Fault, FaultPlan, RecoveryConfig};
        let plan = FaultPlan {
            seed: 11,
            faults: vec![Fault::SpawnFailure { from_s: 0.0, until_s: 50.0, p: 1.0 }],
            recovery: RecoveryConfig::default(),
        };
        let cfg = RouterConfig {
            chaos: Some(Arc::new(ChaosInjector::new(plan))),
            ..Default::default()
        };
        let mut r = Router::new(
            vec![profile(0)],
            FixedTimeout::huawei(),
            CarbonTrace::constant(300.0),
            EnergyModel::default(),
            cfg,
        );
        let a = r.handle(&InvocationRequest { id: 1, t: 0.0, func: 0, exec_s: 0.1 });
        assert!(a.cold);
        // p = 1.0 exhausts the retry budget; latency carries the backoff.
        let rc = RecoveryConfig::default();
        assert_eq!(r.metrics.chaos.spawn_retries, u64::from(rc.max_spawn_retries));
        assert!(a.latency_s > 0.4 + 0.1 + crate::NETWORK_LATENCY_S);
        // Warm arrival inside the window pays nothing.
        let b = r.handle(&InvocationRequest { id: 2, t: 10.0, func: 0, exec_s: 0.1 });
        assert!(!b.cold);
        assert_eq!(r.metrics.chaos.spawn_retries, u64::from(rc.max_spawn_retries));
    }
}
