//! CoordinatorServer: wires driver → router → collector threads and
//! reports end-to-end serving statistics.
//!
//! This is the online-deployment proof (the architecture is "fully
//! compatible with online deployment", §III-A): the same policy objects
//! used in the trace-driven simulator serve a live request stream with
//! decision latencies measured in situ. `examples/e2e_serving.rs` drives
//! the full stack through this server.

use std::sync::mpsc::{channel, sync_channel};
use std::time::Instant;

use crate::carbon::intensity::CarbonTrace;
use crate::chaos::ChaosReport;
use crate::coordinator::driver::{spawn_driver_chaos, Pace};
use crate::coordinator::router::{Router, RouterConfig, RouterMetrics};
use crate::energy::model::EnergyModel;
use crate::policy::KeepAlivePolicy;
use crate::trace::model::Trace;
use crate::util::stats::Ecdf;

/// Serving run report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: u64,
    pub cold_starts: u64,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub mean_latency_s: f64,
    pub mean_decision_us: f64,
    pub p99_decision_us: f64,
    pub keepalive_carbon_g: f64,
    /// Degraded-mode accounting; `Some` iff a fault injector was attached
    /// (zeros under an empty plan).
    pub chaos: Option<ChaosReport>,
}

impl ServeReport {
    fn from_metrics(m: &RouterMetrics, wall_s: f64, p99_decision_us: f64) -> Self {
        ServeReport {
            requests: m.requests,
            cold_starts: m.cold_starts,
            wall_s,
            throughput_rps: m.requests as f64 / wall_s.max(1e-9),
            mean_latency_s: m.latency.mean(),
            mean_decision_us: m.decision_ns.mean() / 1_000.0,
            p99_decision_us,
            keepalive_carbon_g: m.keepalive_carbon_g,
            chaos: None,
        }
    }

    pub fn print(&self, label: &str) {
        println!(
            "[serve:{label}] requests={} cold={} wall={:.2}s throughput={:.0} req/s \
             latency={:.4}s decision(mean/p99)={:.1}/{:.1}µs keepalive={:.4}g",
            self.requests,
            self.cold_starts,
            self.wall_s,
            self.throughput_rps,
            self.mean_latency_s,
            self.mean_decision_us,
            self.p99_decision_us,
            self.keepalive_carbon_g,
        );
        if let Some(ch) = &self.chaos {
            println!("{}", ch.summary_line());
        }
    }
}

/// One-shot serving harness.
pub struct CoordinatorServer;

impl CoordinatorServer {
    /// Replay `trace` through a router running the given policy; returns
    /// the serving report. `queue_depth` bounds the in-flight channel
    /// (backpressure).
    pub fn run<P: KeepAlivePolicy + Send + 'static>(
        trace: &Trace,
        policy: P,
        ci: CarbonTrace,
        energy: EnergyModel,
        cfg: RouterConfig,
        pace: Pace,
        queue_depth: usize,
    ) -> anyhow::Result<(ServeReport, P)> {
        let _serve_span = crate::obs::span("coordinator/serve");
        let chaos = cfg.chaos.clone();
        let router = Router::new(trace.functions.clone(), policy, ci, energy, cfg);
        let (req_tx, req_rx) = sync_channel(queue_depth);
        let (resp_tx, resp_rx) = channel();

        let t0 = Instant::now();
        let driver = spawn_driver_chaos(trace, pace, req_tx, chaos.clone());
        let router_thread = std::thread::spawn(move || router.serve(req_rx, resp_tx));

        // Collect responses on this thread (keeps decision-latency samples).
        let mut decision_us: Vec<f64> = Vec::with_capacity(trace.invocations.len());
        for resp in resp_rx.iter() {
            decision_us.push(resp.decision_ns as f64 / 1_000.0);
        }
        let sent = driver
            .join()
            .map_err(|_| anyhow::anyhow!("driver thread panicked"))?;
        let router = router_thread
            .join()
            .map_err(|_| anyhow::anyhow!("router thread panicked"))?;
        let wall = t0.elapsed().as_secs_f64();

        anyhow::ensure!(
            sent == router.metrics.requests,
            "driver sent {} but router served {}",
            sent,
            router.metrics.requests
        );
        // Build the decision-latency histogram before the ECDF consumes
        // the sample vector (telemetry only; skipped when obs is off).
        let decision_hist = crate::obs::sink().map(|_| {
            let mut h = crate::obs::Hist::new();
            for &us in &decision_us {
                h.record(us / 1e6);
            }
            h
        });
        let p99 = if decision_us.is_empty() {
            0.0
        } else {
            Ecdf::new(decision_us).quantile(0.99)
        };
        let (policy, metrics) = router.into_parts();
        let mut report = ServeReport::from_metrics(&metrics, wall, p99);
        report.chaos = chaos.as_deref().map(|inj| {
            ChaosReport::new(metrics.chaos, inj.stalls_hit(), inj.plan(), metrics.t_end)
        });
        if let Some(sink) = crate::obs::sink() {
            use crate::util::json::Json;
            sink.add_counter("serve/requests", report.requests);
            sink.add_counter("serve/cold_starts", report.cold_starts);
            let mut lines = vec![
                Json::obj(vec![
                    ("kind", "meta".into()),
                    ("stream", "serve".into()),
                    ("policy", policy.name().into()),
                ]),
                Json::obj(vec![
                    ("kind", "serve-report".into()),
                    ("requests", report.requests.into()),
                    ("cold_starts", report.cold_starts.into()),
                    ("wall_s", report.wall_s.into()),
                    ("throughput_rps", report.throughput_rps.into()),
                    ("mean_latency_s", report.mean_latency_s.into()),
                    ("mean_decision_us", report.mean_decision_us.into()),
                    ("p99_decision_us", report.p99_decision_us.into()),
                    ("keepalive_carbon_g", report.keepalive_carbon_g.into()),
                ]),
            ];
            if let Some(h) = &decision_hist {
                lines.push(h.to_json("decision_s"));
            }
            if let Some(ch) = &report.chaos {
                lines.push(Json::obj(vec![
                    ("kind", "chaos".into()),
                    ("report", ch.to_json()),
                ]));
            }
            let stream = format!("serve_{}", policy.name());
            if let Err(e) = sink.emit_jsonl(&stream, &lines) {
                eprintln!("[obs] failed to write serve telemetry: {e}");
            }
        }
        Ok((report, policy))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::fixed::FixedTimeout;
    use crate::trace::synth::{SynthConfig, TraceGenerator};

    #[test]
    fn serves_whole_trace_max_speed() {
        let trace = TraceGenerator::new(SynthConfig {
            n_functions: 10,
            duration_s: 300.0,
            target_invocations: 2_000,
            ..SynthConfig::small(5)
        })
        .generate();
        let (report, _policy) = CoordinatorServer::run(
            &trace,
            FixedTimeout::huawei(),
            CarbonTrace::constant(300.0),
            EnergyModel::default(),
            RouterConfig::default(),
            Pace::MaxSpeed,
            256,
        )
        .unwrap();
        assert_eq!(report.requests as usize, trace.len());
        assert!(report.cold_starts > 0);
        assert!(report.throughput_rps > 100.0);
        assert!(report.mean_latency_s > 0.0);
    }

    #[test]
    fn serving_metrics_match_simulator_cold_counts() {
        // The online router and the offline simulator implement the same
        // semantics; cold-start counts must agree on the same workload.
        let trace = TraceGenerator::new(SynthConfig {
            n_functions: 8,
            duration_s: 400.0,
            target_invocations: 3_000,
            ..SynthConfig::small(6)
        })
        .generate();
        let ci = CarbonTrace::constant(300.0);
        let sim = crate::simulator::engine::Simulator::new(
            &trace,
            &ci,
            EnergyModel::default(),
            crate::simulator::engine::SimConfig::default(),
        );
        let sim_result = sim.run(&mut FixedTimeout::huawei());

        let (report, _) = CoordinatorServer::run(
            &trace,
            FixedTimeout::huawei(),
            ci.clone(),
            EnergyModel::default(),
            RouterConfig::default(),
            Pace::MaxSpeed,
            256,
        )
        .unwrap();
        assert_eq!(report.cold_starts, sim_result.metrics.cold_starts);
        assert!(
            (report.mean_latency_s - sim_result.metrics.avg_latency_s()).abs() < 1e-9
        );
    }
}
