//! FunctionBench energy calibration (paper Table II).
//!
//! The paper validates its simulator constants by profiling a FunctionBench
//! deployment with Kepler on an HPE DL385 (dual EPYC 7513). We cannot
//! re-run that testbed, so Table II is embedded verbatim as the calibration
//! dataset. The simulator consumes only the *derived* constants — λ_idle
//! and per-resource power — and `experiments::table2` regenerates the table
//! plus the λ_idle summary from this data to validate the round trip.

/// One Table II row: phase-level energy profile of a FunctionBench function.
#[derive(Debug, Clone)]
pub struct BenchProfile {
    pub name: &'static str,
    pub input: &'static str,
    pub mem_mb: f64,
    pub cold_start_ms: f64,
    pub compute_ms: f64,
    pub cold_active_j: f64,
    pub compute_active_j: f64,
    pub keepalive_1min_j: f64,
    pub compute_power_w: f64,
    pub keepalive_power_w: f64,
    /// λ_idle = keep-alive / compute total power ratio.
    pub lambda_idle: f64,
}

/// Table II, verbatim from the paper (§IV-A1).
pub const FUNCTIONBENCH: [BenchProfile; 10] = [
    BenchProfile { name: "Float Operations", input: "10,000,000", mem_mb: 44.0, cold_start_ms: 112.2, compute_ms: 3340.86, cold_active_j: 0.94, compute_active_j: 15.08, keepalive_1min_j: 78.29, compute_power_w: 6.37, keepalive_power_w: 3.19, lambda_idle: 0.50 },
    BenchProfile { name: "MatMul", input: "10,000", mem_mb: 95.0, cold_start_ms: 166.5, compute_ms: 2393.41, cold_active_j: 0.27, compute_active_j: 144.41, keepalive_1min_j: 76.98, compute_power_w: 86.64, keepalive_power_w: 28.89, lambda_idle: 0.33 },
    BenchProfile { name: "Linpack", input: "100,000", mem_mb: 97.0, cold_start_ms: 76.33, compute_ms: 6401.45, cold_active_j: 0.7, compute_active_j: 436.9, keepalive_1min_j: 92.4, compute_power_w: 147.29, keepalive_power_w: 70.82, lambda_idle: 0.48 },
    BenchProfile { name: "Image Processing", input: "28.4 MB", mem_mb: 68.0, cold_start_ms: 2441.68, compute_ms: 6761.82, cold_active_j: 11.13, compute_active_j: 20.69, keepalive_1min_j: 81.6, compute_power_w: 4.98, keepalive_power_w: 3.21, lambda_idle: 0.64 },
    BenchProfile { name: "Video Processing", input: "742 KB", mem_mb: 233.0, cold_start_ms: 12414.77, compute_ms: 2403.04, cold_active_j: 19.05, compute_active_j: 6.82, keepalive_1min_j: 72.68, compute_power_w: 4.65, keepalive_power_w: 3.03, lambda_idle: 0.65 },
    BenchProfile { name: "Chameleon", input: "[500,100]", mem_mb: 57.0, cold_start_ms: 71.6, compute_ms: 249.52, cold_active_j: 0.52, compute_active_j: 1.84, keepalive_1min_j: 81.1, compute_power_w: 9.27, keepalive_power_w: 3.14, lambda_idle: 0.34 },
    BenchProfile { name: "pyaes", input: "200 iterations", mem_mb: 42.0, cold_start_ms: 563.17, compute_ms: 1567.58, cold_active_j: 3.41, compute_active_j: 6.34, keepalive_1min_j: 66.78, compute_power_w: 6.02, keepalive_power_w: 2.87, lambda_idle: 0.48 },
    BenchProfile { name: "Feature Extractor", input: "30.5 MB", mem_mb: 133.0, cold_start_ms: 109.31, compute_ms: 2323.78, cold_active_j: 0.15, compute_active_j: 10.40, keepalive_1min_j: 75.04, compute_power_w: 6.33, keepalive_power_w: 3.06, lambda_idle: 0.48 },
    BenchProfile { name: "Model Training", input: "15.23 MB", mem_mb: 172.0, cold_start_ms: 115.58, compute_ms: 2485.6, cold_active_j: 2.96, compute_active_j: 31.66, keepalive_1min_j: 79.2, compute_power_w: 14.56, keepalive_power_w: 3.12, lambda_idle: 0.21 },
    BenchProfile { name: "Classification Image", input: "28.4 MB", mem_mb: 275.0, cold_start_ms: 8642.95, compute_ms: 1591.42, cold_active_j: 21.39, compute_active_j: 2.96, keepalive_1min_j: 71.42, compute_power_w: 3.68, keepalive_power_w: 3.05, lambda_idle: 0.83 },
];

/// Measured λ_idle range across FunctionBench: (min, max, mean).
pub fn lambda_idle_stats() -> (f64, f64, f64) {
    let xs: Vec<f64> = FUNCTIONBENCH.iter().map(|b| b.lambda_idle).collect();
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    (min, max, mean)
}

/// The paper's simulation choice: λ_idle = 0.2, conservative relative to
/// every measured value (§IV-A1).
pub const SIMULATION_LAMBDA_IDLE: f64 = 0.2;

/// Validate the paper's observation that cold-start *duration* predicts
/// cold-start energy: Pearson correlation between `cold_start_ms` and
/// `cold_active_j` across the benchmark suite.
pub fn cold_duration_energy_correlation() -> f64 {
    let xs: Vec<f64> = FUNCTIONBENCH.iter().map(|b| b.cold_start_ms).collect();
    let ys: Vec<f64> = FUNCTIONBENCH.iter().map(|b| b.cold_active_j).collect();
    pearson(&xs, &ys)
}

fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys.iter()) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_range_matches_paper() {
        let (min, max, mean) = lambda_idle_stats();
        assert!((min - 0.21).abs() < 1e-9);
        assert!((max - 0.83).abs() < 1e-9);
        assert!(mean > 0.4 && mean < 0.6);
    }

    #[test]
    fn simulation_lambda_is_conservative() {
        let (min, _, _) = lambda_idle_stats();
        assert!(SIMULATION_LAMBDA_IDLE <= min);
    }

    #[test]
    fn cold_duration_predicts_energy() {
        // Paper: "cold-start phase duration is a good predictor for the
        // respective energy cost" — expect strong positive correlation.
        let r = cold_duration_energy_correlation();
        assert!(r > 0.8, "pearson r = {r}");
    }

    #[test]
    fn table_has_expected_outliers() {
        // Image/Video Processing and Image Classification have the long
        // cold starts the paper calls out.
        let long: Vec<&str> = FUNCTIONBENCH
            .iter()
            .filter(|b| b.cold_start_ms > 2000.0)
            .map(|b| b.name)
            .collect();
        assert_eq!(
            long,
            vec!["Image Processing", "Video Processing", "Classification Image"]
        );
    }

    #[test]
    fn keepalive_power_consistent_with_ratio() {
        for b in &FUNCTIONBENCH {
            let ratio = b.keepalive_power_w / b.compute_power_w;
            assert!(
                (ratio - b.lambda_idle).abs() < 0.02,
                "{}: ratio {ratio} vs lambda {}",
                b.name,
                b.lambda_idle
            );
        }
    }
}
