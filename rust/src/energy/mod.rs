//! Energy & carbon accounting (paper §II-B, Eqs. 1–4).

pub mod calibration;
pub mod model;

pub use model::{EnergyModel, JOULES_PER_KWH};
