//! The paper's operational energy model (Eqs. 1–4) and carbon weighting.
//!
//! Power constants model the m5-family EC2 instance class the paper
//! simulates (§IV-A3): Intel Xeon Platinum 8275CL, 240 W TDP / 24 physical
//! cores (~48 logical), plus DDR4 DRAM at ≈0.37 W/GB. Embodied carbon is
//! excluded (invariant to retention strategy); hardware is homogeneous.

use crate::carbon::intensity::CarbonTrace;

/// J per kWh — converts Joules × (gCO₂/kWh) into grams CO₂.
pub const JOULES_PER_KWH: f64 = 3.6e6;

/// Per-resource power model: all phase energies derive from
/// `(J_DRAM_per_MB · mem + J_CPU_per_core · cpu) · T_phase` (Eqs. 1–2) and
/// the cold-start term `P_cold · T_cold` (Eq. 4).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Active power per allocated CPU core (W). Xeon 8275CL: 240 W TDP /
    /// 24 cores ≈ 10 W; we use 6 W to account for sub-TDP serverless duty.
    pub cpu_w_per_core: f64,
    /// Active DRAM power per MB (W). ≈0.37 W/GB DDR4.
    pub dram_w_per_mb: f64,
    /// Idle scaling factor λ_idle (paper: 0.2, validated 0.21–0.83 in
    /// Table II; 0.2 is the conservative choice).
    pub lambda_idle: f64,
    /// Cold-start power (W) per pod. Table II shows cold-start energy is
    /// dominated by duration, with power close to the pod's active draw;
    /// modeled as the active-power formula times this multiplier.
    pub cold_power_factor: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            cpu_w_per_core: 6.0,
            dram_w_per_mb: 0.37 / 1024.0,
            lambda_idle: 0.2,
            cold_power_factor: 1.0,
        }
    }
}

impl EnergyModel {
    pub fn with_lambda_idle(lambda_idle: f64) -> Self {
        EnergyModel { lambda_idle, ..EnergyModel::default() }
    }

    /// Active pod power draw (W) for a resource allocation.
    #[inline]
    pub fn active_power_w(&self, mem_mb: f64, cpu_cores: f64) -> f64 {
        self.dram_w_per_mb * mem_mb + self.cpu_w_per_core * cpu_cores
    }

    /// Eq. 1 — execution energy (J).
    #[inline]
    pub fn exec_energy_j(&self, mem_mb: f64, cpu_cores: f64, t_exec_s: f64) -> f64 {
        self.active_power_w(mem_mb, cpu_cores) * t_exec_s
    }

    /// Eqs. 2–3 — scaled idle (keep-alive) energy (J) over `t_idle_s`.
    #[inline]
    pub fn idle_energy_j(&self, mem_mb: f64, cpu_cores: f64, t_idle_s: f64) -> f64 {
        self.lambda_idle * self.active_power_w(mem_mb, cpu_cores) * t_idle_s
    }

    /// Eq. 4 — cold-start energy (J) over the cold-start latency.
    #[inline]
    pub fn cold_energy_j(&self, mem_mb: f64, cpu_cores: f64, t_cold_s: f64) -> f64 {
        self.cold_power_factor * self.active_power_w(mem_mb, cpu_cores) * t_cold_s
    }

    /// Convert energy to carbon (g CO₂) at a fixed carbon intensity.
    #[inline]
    pub fn carbon_g(&self, energy_j: f64, ci_g_per_kwh: f64) -> f64 {
        energy_j * ci_g_per_kwh / JOULES_PER_KWH
    }

    /// Carbon (g CO₂) of idle retention over the wall-clock span
    /// [t0, t1], integrating the CI trace across hour boundaries.
    pub fn idle_carbon_g(
        &self,
        mem_mb: f64,
        cpu_cores: f64,
        t0: f64,
        t1: f64,
        ci: &CarbonTrace,
    ) -> f64 {
        let power_w = self.lambda_idle * self.active_power_w(mem_mb, cpu_cores);
        power_w * ci.integrate(t0, t1) / JOULES_PER_KWH
    }

    /// Carbon (g CO₂) of an execution starting at `t` (CI held constant
    /// within the short execution window, per the paper's assumption).
    pub fn exec_carbon_g(
        &self,
        mem_mb: f64,
        cpu_cores: f64,
        t: f64,
        t_exec_s: f64,
        ci: &CarbonTrace,
    ) -> f64 {
        self.carbon_g(self.exec_energy_j(mem_mb, cpu_cores, t_exec_s), ci.at(t))
    }

    /// Carbon (g CO₂) of a cold start at time `t`.
    pub fn cold_carbon_g(
        &self,
        mem_mb: f64,
        cpu_cores: f64,
        t: f64,
        t_cold_s: f64,
        ci: &CarbonTrace,
    ) -> f64 {
        self.carbon_g(self.cold_energy_j(mem_mb, cpu_cores, t_cold_s), ci.at(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn active_power_composition() {
        let m = EnergyModel::default();
        let p = m.active_power_w(1024.0, 2.0);
        assert!((p - (0.37 + 12.0)).abs() < 1e-9);
    }

    #[test]
    fn idle_scales_by_lambda() {
        let m = EnergyModel::default();
        let active = m.exec_energy_j(100.0, 1.0, 60.0);
        let idle = m.idle_energy_j(100.0, 1.0, 60.0);
        assert!((idle / active - 0.2).abs() < 1e-12);
    }

    #[test]
    fn carbon_conversion() {
        let m = EnergyModel::default();
        // 1 kWh at 500 g/kWh = 500 g.
        assert!((m.carbon_g(JOULES_PER_KWH, 500.0) - 500.0).abs() < 1e-9);
    }

    #[test]
    fn idle_carbon_integrates_ci() {
        let m = EnergyModel::with_lambda_idle(1.0);
        let ci = CarbonTrace::new("t", 10.0, vec![100.0, 300.0]);
        // power = active_power(0 MB, 1 core) = 6 W over [5, 15]
        // carbon = 6 * (5*100 + 5*300) / 3.6e6
        let got = m.idle_carbon_g(0.0, 1.0, 5.0, 15.0, &ci);
        let want = 6.0 * 2000.0 / JOULES_PER_KWH;
        assert!((got - want).abs() < 1e-12);
    }

    #[test]
    fn higher_ci_higher_exec_carbon() {
        let m = EnergyModel::default();
        let ci = CarbonTrace::new("t", 3600.0, vec![100.0, 600.0]);
        let low = m.exec_carbon_g(64.0, 1.0, 0.0, 1.0, &ci);
        let high = m.exec_carbon_g(64.0, 1.0, 3600.0, 1.0, &ci);
        assert!((high / low - 6.0).abs() < 1e-9);
    }

    #[test]
    fn zero_duration_zero_energy() {
        let m = EnergyModel::default();
        assert_eq!(m.exec_energy_j(100.0, 1.0, 0.0), 0.0);
        let ci = CarbonTrace::constant(300.0);
        assert_eq!(m.idle_carbon_g(100.0, 1.0, 5.0, 5.0, &ci), 0.0);
    }
}
