//! Ablations of DESIGN.md's called-out choices (plus the paper's §IV-F
//! λ_idle sensitivity):
//!
//! 1. **λ_idle sweep** — the energy model's idle scaling factor across the
//!    measured FunctionBench range (0.1 … 0.83). Keep-alive carbon scales
//!    linearly; the paper's 0.2 is conservative, larger values strengthen
//!    the case for adaptive retention.
//! 2. **Reuse-window size W** — the state encoder's history length.
//! 3. **Carbon-blindness** — LACE-RL evaluated against a constant-CI grid:
//!    how much of the saving comes from temporal carbon awareness vs pure
//!    reuse prediction.

use crate::carbon::intensity::CarbonTrace;
use crate::energy::model::EnergyModel;
use crate::experiments::workload;
use crate::policy::FixedTimeout;
use crate::simulator::engine::SimConfig;
use crate::simulator::parallel::{BoxedPolicy, SweepCell, SweepRunner};

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    let w = workload::build(seed, quick);
    let params = workload::lace_rl_params()?;
    let runner = SweepRunner::new(&w.general, &w.ci, w.energy.clone());

    // ---- 1. λ_idle sweep (paper §IV-F) ----
    println!("Ablation 1 — λ_idle sensitivity (Huawei static baseline, General workload):");
    println!("  {:>8} {:>18} {:>14}", "λ_idle", "keepalive (g)", "total (g)");
    const LAMBDAS: [f64; 4] = [0.1, 0.2, 0.5, 0.83];
    let cells = LAMBDAS
        .iter()
        .map(|&lam| {
            SweepCell::new(format!("λ_idle={lam}"), SimConfig::default(), || {
                Box::new(FixedTimeout::huawei()) as BoxedPolicy
            })
            .with_energy(EnergyModel::with_lambda_idle(lam))
        })
        .collect();
    let outcomes = runner.run(cells);
    let base = outcomes[0].result.metrics.keepalive_carbon_g;
    for (lam, o) in LAMBDAS.iter().zip(outcomes.iter()) {
        let m = &o.result.metrics;
        println!("  {lam:>8.2} {:>18.3} {:>14.3}", m.keepalive_carbon_g, m.total_carbon_g());
        let ratio = m.keepalive_carbon_g / base;
        anyhow::ensure!(
            (ratio - lam / 0.1).abs() < 0.02 * (lam / 0.1),
            "keep-alive carbon must scale linearly in λ_idle (got ×{ratio:.3} at λ={lam})"
        );
    }
    println!("  (linear scaling verified — λ_idle=0.2 is conservative vs measured 0.21–0.83)");

    // ---- 2. Reuse-window size ----
    println!("\nAblation 2 — reuse-window W (LACE-RL state quality):");
    println!("  {:>6} {:>12} {:>18}", "W", "cold starts", "keepalive (g)");
    const WINDOWS: [usize; 4] = [8, 32, 64, 256];
    let cells = WINDOWS
        .iter()
        .map(|&window| {
            let p = params.clone();
            SweepCell::new(
                format!("W={window}"),
                SimConfig { reuse_window: window, ..SimConfig::default() },
                move || Box::new(workload::lace_rl_from_params(&p)) as BoxedPolicy,
            )
        })
        .collect();
    for (window, o) in WINDOWS.iter().zip(runner.run(cells).iter()) {
        let m = &o.result.metrics;
        println!("  {window:>6} {:>12} {:>18.3}", m.cold_starts, m.keepalive_carbon_g);
    }

    // ---- 3. Carbon-aware vs carbon-blind ----
    println!("\nAblation 3 — temporal carbon awareness:");
    let mean_ci = w.ci.values.iter().sum::<f64>() / w.ci.values.len() as f64;
    let flat = CarbonTrace::constant(mean_ci);
    let p_aware = params.clone();
    let p_blind = params;
    let outcomes = runner.run(vec![
        SweepCell::new("ci-aware", SimConfig::default(), move || {
            Box::new(workload::lace_rl_from_params(&p_aware)) as BoxedPolicy
        }),
        SweepCell::new("ci-blind", SimConfig::default(), move || {
            Box::new(workload::lace_rl_from_params(&p_blind)) as BoxedPolicy
        })
        .with_ci(&flat),
    ]);
    let (aware, blind) = (&outcomes[0].result.metrics, &outcomes[1].result.metrics);
    println!(
        "  varying CI : cold={} keepalive={:.3}g",
        aware.cold_starts, aware.keepalive_carbon_g
    );
    println!(
        "  constant CI: cold={} keepalive={:.3}g (same mean intensity)",
        blind.cold_starts, blind.keepalive_carbon_g
    );
    println!("  Δ = how much headroom the CI signal gives the learned policy");
    Ok(())
}
