//! Ablations of DESIGN.md's called-out choices (plus the paper's §IV-F
//! λ_idle sensitivity):
//!
//! 1. **λ_idle sweep** — the energy model's idle scaling factor across the
//!    measured FunctionBench range (0.1 … 0.83). Keep-alive carbon scales
//!    linearly; the paper's 0.2 is conservative, larger values strengthen
//!    the case for adaptive retention.
//! 2. **Reuse-window size W** — the state encoder's history length.
//! 3. **Carbon-blindness** — LACE-RL evaluated against a constant-CI grid:
//!    how much of the saving comes from temporal carbon awareness vs pure
//!    reuse prediction.

use crate::carbon::intensity::CarbonTrace;
use crate::energy::model::EnergyModel;
use crate::experiments::workload;
use crate::policy::FixedTimeout;
use crate::simulator::engine::{SimConfig, Simulator};

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    let w = workload::build(seed, quick);

    // ---- 1. λ_idle sweep (paper §IV-F) ----
    println!("Ablation 1 — λ_idle sensitivity (Huawei static baseline, General workload):");
    println!("  {:>8} {:>18} {:>14}", "λ_idle", "keepalive (g)", "total (g)");
    let mut base = None;
    for lam in [0.1, 0.2, 0.5, 0.83] {
        let energy = EnergyModel::with_lambda_idle(lam);
        let sim = Simulator::new(&w.general, &w.ci, energy, SimConfig::default());
        let m = sim.run(&mut FixedTimeout::huawei()).metrics;
        println!("  {lam:>8.2} {:>18.3} {:>14.3}", m.keepalive_carbon_g, m.total_carbon_g());
        if lam == 0.1 {
            base = Some(m.keepalive_carbon_g);
        } else if let Some(b) = base {
            let ratio = m.keepalive_carbon_g / b;
            anyhow::ensure!(
                (ratio - lam / 0.1).abs() < 0.02 * (lam / 0.1),
                "keep-alive carbon must scale linearly in λ_idle (got ×{ratio:.3} at λ={lam})"
            );
        }
    }
    println!("  (linear scaling verified — λ_idle=0.2 is conservative vs measured 0.21–0.83)");

    // ---- 2. Reuse-window size ----
    println!("\nAblation 2 — reuse-window W (LACE-RL state quality):");
    println!("  {:>6} {:>12} {:>18}", "W", "cold starts", "keepalive (g)");
    for window in [8usize, 32, 64, 256] {
        let mut lace = workload::lace_rl_policy()?;
        let cfg = SimConfig { reuse_window: window, ..SimConfig::default() };
        let sim = Simulator::new(&w.general, &w.ci, w.energy.clone(), cfg);
        let m = sim.run(&mut lace).metrics;
        println!("  {window:>6} {:>12} {:>18.3}", m.cold_starts, m.keepalive_carbon_g);
    }

    // ---- 3. Carbon-aware vs carbon-blind ----
    println!("\nAblation 3 — temporal carbon awareness:");
    let mean_ci = w.ci.values.iter().sum::<f64>() / w.ci.values.len() as f64;
    let flat = CarbonTrace::constant(mean_ci);
    let mut lace = workload::lace_rl_policy()?;
    let aware = workload::evaluate(&w.general, &w.ci, &w.energy, &mut lace, 0.5, false);
    let mut lace = workload::lace_rl_policy()?;
    let blind = {
        let cfg = SimConfig::default();
        let sim = Simulator::new(&w.general, &flat, w.energy.clone(), cfg);
        sim.run(&mut lace).metrics
    };
    println!(
        "  varying CI : cold={} keepalive={:.3}g",
        aware.cold_starts, aware.keepalive_carbon_g
    );
    println!(
        "  constant CI: cold={} keepalive={:.3}g (same mean intensity)",
        blind.cold_starts, blind.keepalive_carbon_g
    );
    println!("  Δ = how much headroom the CI signal gives the learned policy");
    Ok(())
}
