//! §IV-E — inference and training cost: total decision time over the
//! Long-tailed workload for LACE-RL (native fast path and the AOT PJRT
//! path) vs the DPSO metaheuristic, reproducing the paper's
//! "microseconds vs. iterative population updates" comparison
//! (15 µs/invocation vs 4,600× slower for DPSO in the paper).

use std::time::Instant;

use crate::experiments::workload;
use crate::policy::dpso::{Dpso, DpsoConfig};
use crate::policy::KeepAlivePolicy;
use crate::rl::encoder::encode;

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    let w = workload::build(seed, quick);
    let trace = &w.long_tailed;
    println!(
        "decision-cost comparison over the Long-tailed workload ({} invocations)\n",
        trace.len()
    );

    // Build a decision-context stream by simulating once with a recorder,
    // then replay identical contexts through each policy's decide() alone —
    // isolating decision cost from simulation cost.
    let contexts = collect_contexts(&w, trace);
    println!("collected {} decision points", contexts.len());

    // LACE-RL native
    let mut lace = workload::lace_rl_policy()?;
    let t0 = Instant::now();
    let mut sink = 0usize;
    for ctx in contexts.iter() {
        sink = sink.wrapping_add(decide_ctx(&mut lace, &w, ctx));
    }
    let lace_total = t0.elapsed();

    // DPSO
    let mut dpso = Dpso::new(DpsoConfig::default());
    let t0 = Instant::now();
    for ctx in contexts.iter() {
        sink = sink.wrapping_add(decide_ctx(&mut dpso, &w, ctx));
    }
    let dpso_total = t0.elapsed();
    std::hint::black_box(sink);

    let n = contexts.len() as f64;
    let lace_us = lace_total.as_secs_f64() * 1e6 / n;
    let dpso_us = dpso_total.as_secs_f64() * 1e6 / n;
    println!("\n{:<16} {:>14} {:>16}", "policy", "total (s)", "per-decision");
    println!(
        "{:<16} {:>14.4} {:>13.2} µs",
        "lace-rl(native)", lace_total.as_secs_f64(), lace_us
    );
    println!(
        "{:<16} {:>14.4} {:>13.2} µs",
        "dpso-ecolife", dpso_total.as_secs_f64(), dpso_us
    );
    println!(
        "\nDPSO / LACE-RL slowdown: {:.0}× (paper: 4,600× vs their DPSO implementation)",
        dpso_us / lace_us
    );
    println!("training cost: see `lace-rl train` output (per-episode wall time)");
    anyhow::ensure!(dpso_us > lace_us * 5.0, "DPSO should be ≫ slower than the DQN");
    Ok(())
}

/// Snapshot of a decision context (owned, replayable).
#[derive(Clone)]
pub struct CtxSnapshot {
    pub t: f64,
    pub func: u32,
    pub ci: f64,
    pub reuse_probs: [f64; 5],
    pub idle_power_w: f64,
}

/// Collect the decision-context stream via a sweep cell. The recorder
/// policy is constructed inside the runner, so it streams into shared
/// storage the caller keeps a handle on.
fn collect_contexts(w: &workload::Workload, trace: &crate::trace::model::Trace) -> Vec<CtxSnapshot> {
    use crate::simulator::engine::SimConfig;
    use crate::simulator::parallel::{BoxedPolicy, SweepCell, SweepRunner};
    use std::sync::{Arc, Mutex};

    struct Collector {
        out: Arc<Mutex<Vec<CtxSnapshot>>>,
    }
    impl KeepAlivePolicy for Collector {
        fn name(&self) -> &str {
            "collector"
        }
        fn decide(&mut self, ctx: &crate::policy::DecisionContext) -> usize {
            self.out.lock().unwrap().push(CtxSnapshot {
                t: ctx.t,
                func: ctx.func.id,
                ci: ctx.ci,
                reuse_probs: ctx.reuse_probs,
                idle_power_w: ctx.idle_power_w,
            });
            4
        }
    }

    let out = Arc::new(Mutex::new(Vec::with_capacity(trace.len())));
    let sink = out.clone();
    let cells = vec![SweepCell::new("collect-contexts", SimConfig::default(), move || {
        Box::new(Collector { out: sink.clone() }) as BoxedPolicy
    })];
    SweepRunner::new(trace, &w.ci, w.energy.clone()).run(cells);
    let mut guard = out.lock().unwrap();
    std::mem::take(&mut *guard)
}

fn decide_ctx(
    policy: &mut dyn KeepAlivePolicy,
    w: &workload::Workload,
    snap: &CtxSnapshot,
) -> usize {
    let ctx = crate::policy::DecisionContext {
        t: snap.t,
        func: &w.general.functions[snap.func as usize],
        ci: snap.ci,
        reuse_probs: snap.reuse_probs,
        lambda_carbon: 0.5,
        idle_power_w: snap.idle_power_w,
        next_arrival_gap: None,
    };
    // Touch encode so the native path includes feature construction.
    std::hint::black_box(encode(&ctx));
    policy.decide(&ctx)
}
