//! Fig. 1 — trace characterization: (a) CDF of per-pod average reuse
//! intervals, (b) cold-start latency CDF with the long tail highlighted.

use crate::experiments::{results_dir, workload};
use crate::trace::stats;
use crate::trace::synth::TraceGenerator;
use crate::util::csv::Writer;

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    let trace = TraceGenerator::new(workload::synth_config(seed, quick)).generate();
    println!(
        "workload: {} invocations, {} functions, {:.1}h span",
        trace.len(),
        trace.functions.len(),
        trace.duration_s() / 3600.0
    );

    // (a) reuse interval CDF
    let reuse = stats::reuse_interval_cdf(&trace);
    println!("\nFig 1a — CDF of average reuse interval per pod ({} pods):", reuse.len());
    print_cdf_markers(&reuse, &[0.1, 1.0, 5.0, 10.0, 30.0, 60.0, 300.0, 1000.0], "s");

    // (b) cold start latency CDF
    let cold = stats::cold_start_cdf(&trace);
    println!("\nFig 1b — cold-start latency CDF (per invocation):");
    print_cdf_markers(&cold, &[0.1, 0.25, 0.5, 1.0, 2.0, 5.0, 10.0], "s");
    let tail = 1.0 - cold.eval(1.0);
    println!("  distribution tail (>1s, gray area): {:.1}% of invocations", tail * 100.0);

    // CSV dumps for plotting.
    let dir = results_dir();
    for (name, cdf) in [("fig1a_reuse_cdf", &reuse), ("fig1b_cold_cdf", &cold)] {
        let f = std::fs::File::create(dir.join(format!("{name}.csv")))?;
        let mut w = Writer::new(std::io::BufWriter::new(f), &["value", "cdf"])?;
        for (x, q) in cdf.curve(200) {
            w.row(&[format!("{x:.6}"), format!("{q:.4}")])?;
        }
    }
    println!("\nwrote results/fig1a_reuse_cdf.csv, results/fig1b_cold_cdf.csv");

    // Paper-shape assertions (§II-C): spread over orders of magnitude.
    anyhow::ensure!(reuse.max() / reuse.min().max(1e-3) > 100.0, "reuse spread too narrow");
    anyhow::ensure!(cold.max() > 8.0 && cold.min() < 0.2, "cold-start tail missing");
    Ok(())
}

fn print_cdf_markers(cdf: &crate::util::stats::Ecdf, xs: &[f64], unit: &str) {
    for &x in xs {
        println!("  P[X <= {x:>7.2}{unit}] = {:.3}", cdf.eval(x));
    }
}
