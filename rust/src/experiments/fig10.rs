//! Fig. 10 — sensitivity & interpretability: (a) λ_carbon sweep 0.1→0.9
//! trades cold starts against keep-alive carbon; (b) selection frequency
//! of representative keep-alive durations vs hourly carbon intensity —
//! the learned policy should choose long timeouts in green hours and
//! short ones in dirty hours.

use crate::experiments::{results_dir, workload};
use crate::util::csv::Writer;

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    let w = workload::build(seed, quick);

    // ---- (a) λ sweep ----
    println!("Fig 10a — λ_carbon sensitivity (General workload):");
    println!("  {:>8} {:>12} {:>18}", "λ", "cold starts", "keepalive (g)");
    let dir = results_dir();
    let f = std::fs::File::create(dir.join("fig10a_lambda_sweep.csv"))?;
    let mut csv = Writer::new(
        std::io::BufWriter::new(f),
        &["lambda", "cold_starts", "keepalive_carbon_g"],
    )?;
    let mut series = Vec::new();
    for i in 1..=9 {
        let lambda = i as f64 / 10.0;
        let mut lace = workload::lace_rl_policy()?;
        let m = workload::evaluate(&w.general, &w.ci, &w.energy, &mut lace, lambda, false);
        println!("  {lambda:>8.1} {:>12} {:>18.4}", m.cold_starts, m.keepalive_carbon_g);
        csv.row(&[
            format!("{lambda}"),
            format!("{}", m.cold_starts),
            format!("{:.6}", m.keepalive_carbon_g),
        ])?;
        series.push((lambda, m.cold_starts, m.keepalive_carbon_g));
    }
    // Shape check: the λ dial must move both metrics in the right
    // direction end-to-end (monotone trend, not necessarily per-step).
    let first = series.first().unwrap();
    let last = series.last().unwrap();
    println!(
        "  λ 0.1→0.9: cold starts {}→{} ({:+.1}%), keepalive {:.3}g→{:.3}g ({:+.1}%)",
        first.1, last.1,
        100.0 * (last.1 as f64 - first.1 as f64) / first.1.max(1) as f64,
        first.2, last.2,
        100.0 * (last.2 - first.2) / first.2.max(1e-12),
    );

    // ---- (b) action mix vs hourly CI ----
    println!("\nFig 10b — keep-alive selection frequency vs hourly carbon intensity:");
    let mut lace = workload::lace_rl_policy()?.recording();
    let _ = workload::evaluate(&w.general, &w.ci, &w.energy, &mut lace, 0.5, false);
    // Bucket decisions by hour-of-day.
    let mut per_hour = vec![[0u64; 5]; 24];
    for d in &lace.decisions {
        let hour = ((d.t / 3600.0).floor() as usize) % 24;
        per_hour[hour][d.action] += 1;
    }
    println!(
        "  {:>4} {:>9} {:>8} {:>8} {:>8}  (representative durations)",
        "hour", "CI(g/kWh)", "1s%", "10s%", "60s%"
    );
    let f = std::fs::File::create(dir.join("fig10b_action_mix.csv"))?;
    let mut csv = Writer::new(
        std::io::BufWriter::new(f),
        &["hour", "ci", "pct_1s", "pct_10s", "pct_60s"],
    )?;
    let mut green_60 = 0.0;
    let mut dirty_60 = 0.0;
    let mut green_n = 0;
    let mut dirty_n = 0;
    let ci_mid = (w.ci.min() + w.ci.max()) / 2.0;
    for hour in 0..24 {
        let counts = per_hour[hour];
        let total: u64 = counts.iter().sum();
        if total == 0 {
            continue;
        }
        let pct = |a: usize| 100.0 * counts[a] as f64 / total as f64;
        let ci = w.ci.values[hour];
        println!(
            "  {hour:>4} {ci:>9.0} {:>7.1}% {:>7.1}% {:>7.1}%",
            pct(0),
            pct(2),
            pct(4)
        );
        csv.row(&[
            format!("{hour}"),
            format!("{ci:.1}"),
            format!("{:.2}", pct(0)),
            format!("{:.2}", pct(2)),
            format!("{:.2}", pct(4)),
        ])?;
        if ci < ci_mid {
            green_60 += pct(4);
            green_n += 1;
        } else {
            dirty_60 += pct(4);
            dirty_n += 1;
        }
    }
    if green_n > 0 && dirty_n > 0 {
        println!(
            "\n  60s-share in green hours: {:.1}%   in dirty hours: {:.1}%",
            green_60 / green_n as f64,
            dirty_60 / dirty_n as f64
        );
    }
    println!("\nwrote results/fig10a_lambda_sweep.csv, results/fig10b_action_mix.csv");
    Ok(())
}
