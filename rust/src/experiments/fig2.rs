//! Fig. 2 — impact of keep-alive timeout for two representative functions:
//! longer timeouts cut cold starts monotonically but inflate idle carbon;
//! for low-rate functions idle carbon overtakes execution carbon.

use crate::carbon::intensity::CarbonTrace;
use crate::experiments::{results_dir, workload};
use crate::policy::fixed::FixedTimeout;
use crate::simulator::engine::SimConfig;
use crate::simulator::sharded::ShardedSimulator;
use crate::trace::model::Trace;
use crate::trace::stats;
use crate::trace::synth::TraceGenerator;
use crate::util::csv::Writer;

const TIMEOUTS: [f64; 6] = [1.0, 5.0, 10.0, 30.0, 60.0, 120.0];

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    let trace = TraceGenerator::new(workload::synth_config(seed, quick)).generate();
    let ci = CarbonTrace::constant(400.0); // isolate the timeout effect

    // Representative functions: (hot) frequently-reused with short cold
    // start; (sparse) low-rate where idle carbon can dominate execution.
    let counts = stats::invocation_counts(&trace);
    let hot = pick(&trace, &counts, |c, _gap| c >= 500);
    let sparse = pick(&trace, &counts, |c, gap| (30..200).contains(&c) && gap > 30.0);
    let (hot, sparse) = match (hot, sparse) {
        (Some(h), Some(s)) => (h, s),
        _ => anyhow::bail!("workload too small to pick representative functions; rerun without --quick"),
    };

    let dir = results_dir();
    for (label, func) in [("hot", hot), ("sparse", sparse)] {
        let sub = single_function(&trace, func);
        println!(
            "\nFig 2 ({label}) — function {func}: {} invocations, cold_start={:.2}s",
            sub.len(),
            sub.profile(func).cold_start_s
        );
        println!(
            "  {:>9} {:>12} {:>16} {:>14}",
            "timeout", "cold starts", "idle carbon (g)", "exec carbon (g)"
        );
        let f = std::fs::File::create(dir.join(format!("fig2_{label}.csv")))?;
        let mut w = Writer::new(
            std::io::BufWriter::new(f),
            &["timeout_s", "cold_starts", "idle_carbon_g", "exec_carbon_g"],
        )?;
        let mut prev_cold = u64::MAX;
        let mut prev_idle = -1.0;
        for &timeout in TIMEOUTS.iter() {
            let sim = ShardedSimulator::new(&sub, &ci, workload_energy(), SimConfig::default());
            // FixedTimeout snaps to the action grid; for 120s reuse 60s twice
            // is not expressible, so extend the grid by running 60s twice —
            // instead just snap (documented: action set caps at 60s; the
            // 120s column reports the 60s action, the paper's max).
            let mut p = FixedTimeout::new(timeout);
            let r = sim.run(&mut p);
            println!(
                "  {:>8.0}s {:>12} {:>16.4} {:>14.4}",
                timeout,
                r.metrics.cold_starts,
                r.metrics.keepalive_carbon_g,
                r.metrics.exec_carbon_g
            );
            w.row(&[
                format!("{timeout}"),
                format!("{}", r.metrics.cold_starts),
                format!("{:.6}", r.metrics.keepalive_carbon_g),
                format!("{:.6}", r.metrics.exec_carbon_g),
            ])?;
            // Paper shape: cold starts non-increasing, idle carbon
            // non-decreasing in the timeout.
            anyhow::ensure!(r.metrics.cold_starts <= prev_cold, "cold starts not monotone");
            anyhow::ensure!(
                r.metrics.keepalive_carbon_g >= prev_idle - 1e-9,
                "idle carbon not monotone"
            );
            prev_cold = r.metrics.cold_starts;
            prev_idle = r.metrics.keepalive_carbon_g;
        }
    }
    println!("\nwrote results/fig2_hot.csv, results/fig2_sparse.csv");
    Ok(())
}

fn workload_energy() -> crate::energy::model::EnergyModel {
    crate::energy::model::EnergyModel::default()
}

fn pick(
    trace: &Trace,
    counts: &[u64],
    pred: impl Fn(u64, f64) -> bool,
) -> Option<u32> {
    let means = {
        // mean reuse gap per function, aligned with function ids
        let mut last = vec![f64::NEG_INFINITY; trace.functions.len()];
        let mut sums = vec![0.0; trace.functions.len()];
        let mut n = vec![0u64; trace.functions.len()];
        for inv in &trace.invocations {
            let f = inv.func as usize;
            if last[f] > f64::NEG_INFINITY {
                sums[f] += inv.t - last[f];
                n[f] += 1;
            }
            last[f] = inv.t;
        }
        sums.iter()
            .zip(n.iter())
            .map(|(&s, &c)| if c > 0 { s / c as f64 } else { f64::INFINITY })
            .collect::<Vec<_>>()
    };
    (0..trace.functions.len())
        .find(|&f| pred(counts[f], means[f]))
        .map(|f| f as u32)
}

fn single_function(trace: &Trace, func: u32) -> Trace {
    Trace::new(
        trace.functions.clone(),
        trace
            .invocations
            .iter()
            .filter(|i| i.func == func)
            .copied()
            .collect(),
    )
}
