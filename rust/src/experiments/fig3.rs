//! Fig. 3 — (a) hourly carbon-intensity profiles across the three region
//! archetypes; (b) function memory-footprint CDF.

use crate::carbon::synth::{synth_region, Region};
use crate::experiments::{results_dir, workload};
use crate::trace::stats;
use crate::trace::synth::TraceGenerator;
use crate::util::csv::Writer;

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    // (a) hourly CI profiles
    println!("Fig 3a — hourly carbon intensity (gCO₂eq/kWh):");
    println!("  {:>4} {:>22} {:>22} {:>22}", "hour",
        Region::SolarHeavy.name(), Region::FossilHeavy.name(), Region::HydroLow.name());
    let traces: Vec<_> = Region::ALL
        .iter()
        .map(|&r| synth_region(r, 1, seed))
        .collect();
    let dir = results_dir();
    let f = std::fs::File::create(dir.join("fig3a_ci_profiles.csv"))?;
    let mut w = Writer::new(
        std::io::BufWriter::new(f),
        &["hour", "solar_heavy", "fossil_heavy", "hydro_low"],
    )?;
    for hour in 0..24 {
        let vals: Vec<f64> = traces.iter().map(|t| t.values[hour]).collect();
        println!(
            "  {:>4} {:>22.1} {:>22.1} {:>22.1}",
            hour, vals[0], vals[1], vals[2]
        );
        w.row(&[
            format!("{hour}"),
            format!("{:.2}", vals[0]),
            format!("{:.2}", vals[1]),
            format!("{:.2}", vals[2]),
        ])?;
    }
    let solar = &traces[0];
    let variation = solar.max() / solar.min();
    println!("  solar-heavy daily max/min ratio: {variation:.2}x (temporal variability)");
    anyhow::ensure!(variation > 1.5, "solar region lacks the duck-curve dip");

    // (b) memory footprint CDF
    let trace = TraceGenerator::new(workload::synth_config(seed, quick)).generate();
    let mem = stats::memory_cdf(&trace);
    println!("\nFig 3b — function memory footprint CDF:");
    for mb in [32.0, 64.0, 100.0, 200.0, 512.0, 1024.0] {
        println!("  P[mem <= {mb:>6.0} MB] = {:.3}", mem.eval(mb));
    }
    let f = std::fs::File::create(dir.join("fig3b_memory_cdf.csv"))?;
    let mut w = Writer::new(std::io::BufWriter::new(f), &["mem_mb", "cdf"])?;
    for (x, q) in mem.curve(200) {
        w.row(&[format!("{x:.2}"), format!("{q:.4}")])?;
    }
    println!(
        "  majority below 200 MB: P = {:.3} (paper: >80% under 100 MB-class)",
        mem.eval(200.0)
    );
    println!("\nwrote results/fig3a_ci_profiles.csv, results/fig3b_memory_cdf.csv");
    Ok(())
}
