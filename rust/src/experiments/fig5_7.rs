//! Figs. 5–7 — General workload: absolute metrics across the five
//! policies (Fig. 5), the normalized cold-start/carbon trade-off scatter
//! (Fig. 6), and the composite LCP/IRI metrics (Fig. 7).

use crate::experiments::{results_dir, workload};
use crate::metrics::Comparison;
use crate::policy::dpso::DpsoConfig;
use crate::policy::{CarbonMin, Dpso, FixedTimeout, LatencyMin};
use crate::simulator::engine::SimConfig;
use crate::simulator::parallel::{BoxedPolicy, SweepCell, SweepRunner};
use crate::util::csv::Writer;

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    let w = workload::build(seed, quick);
    println!(
        "General workload: {} invocations over {:.1}h ({} functions)",
        w.general.len(),
        w.general.duration_s() / 3600.0,
        w.general.functions.len()
    );
    let cmp = compare(&w.general, &w, 0.5, "general")?;

    println!("\nFig 5 — absolute metrics:");
    print!("{}", cmp.table());

    println!("Fig 6 — normalized trade-off (1.0 = best in class; ideal is bottom-left):");
    let dir = results_dir();
    let f = std::fs::File::create(dir.join("fig6_tradeoff.csv"))?;
    let mut csv = Writer::new(
        std::io::BufWriter::new(f),
        &["policy", "cold_vs_best", "carbon_vs_best"],
    )?;
    for (name, cold, carbon) in cmp.tradeoff_coordinates() {
        println!("  {name:<16} cold×{cold:<8.2} keepalive-carbon×{carbon:.2}");
        csv.row(&[name, format!("{cold:.4}"), format!("{carbon:.4}")])?;
    }

    println!("\nFig 7 — composite metrics (lower is better):");
    println!("  best LCP: {:?}   best IRI: {:?}", cmp.best_lcp(), cmp.best_iri());

    // Paper-shape checks: LACE-RL beats Huawei on both cold starts and
    // keep-alive carbon, and wins both composites.
    let lace = &cmp.get("lace-rl").unwrap().metrics;
    let huawei = &cmp.get("huawei-60s").unwrap().metrics;
    println!(
        "\nvs Huawei static: cold starts {:.1}% lower, keep-alive carbon {:.1}% lower",
        100.0 * (1.0 - lace.cold_starts as f64 / huawei.cold_starts as f64),
        100.0 * (1.0 - lace.keepalive_carbon_g / huawei.keepalive_carbon_g),
    );
    Ok(())
}

/// Run the standard five-policy comparison (Oracle excluded here; it gets
/// its own Table III experiment). All five cells execute in parallel on the
/// sweep runner; results are deterministic and ordered. `name` labels the
/// workload in the comparison and in the per-policy telemetry streams
/// (`results/obs/<name>_<policy>.jsonl` when an obs sink is installed).
pub fn compare(
    trace: &crate::trace::model::Trace,
    w: &workload::Workload,
    lambda: f64,
    name: &str,
) -> anyhow::Result<Comparison> {
    let params = workload::lace_rl_params()?;
    let cfg = SimConfig { lambda_carbon: lambda, ..SimConfig::default() };
    let cells = vec![
        SweepCell::new("latency-min", cfg.clone(), || Box::new(LatencyMin) as BoxedPolicy),
        SweepCell::new("carbon-min", cfg.clone(), || Box::new(CarbonMin) as BoxedPolicy),
        SweepCell::new("huawei-60s", cfg.clone(), || {
            Box::new(FixedTimeout::huawei()) as BoxedPolicy
        }),
        SweepCell::new("dpso-ecolife", cfg.clone(), || {
            Box::new(Dpso::new(DpsoConfig::default())) as BoxedPolicy
        }),
        SweepCell::new("lace-rl", cfg, move || {
            Box::new(workload::lace_rl_from_params(&params)) as BoxedPolicy
        }),
    ];
    let runner = SweepRunner::new(trace, &w.ci, w.energy.clone());
    let mut cmp = Comparison::new(name);
    for outcome in runner.run(cells) {
        if let Some(obs) = &outcome.result.obs {
            crate::obs::emit_sim(&format!("{name}_{}", outcome.label), obs);
        }
        cmp.add(&outcome.label, outcome.result.metrics);
    }
    Ok(cmp)
}
