//! Figs. 5–7 — General workload: absolute metrics across the five
//! policies (Fig. 5), the normalized cold-start/carbon trade-off scatter
//! (Fig. 6), and the composite LCP/IRI metrics (Fig. 7).

use crate::experiments::{results_dir, workload};
use crate::metrics::Comparison;
use crate::policy::{CarbonMin, Dpso, FixedTimeout, LatencyMin};
use crate::policy::dpso::DpsoConfig;
use crate::util::csv::Writer;

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    let w = workload::build(seed, quick);
    println!(
        "General workload: {} invocations over {:.1}h ({} functions)",
        w.general.len(),
        w.general.duration_s() / 3600.0,
        w.general.functions.len()
    );
    let cmp = compare(&w.general, &w, 0.5)?;

    println!("\nFig 5 — absolute metrics:");
    print!("{}", cmp.table());

    println!("Fig 6 — normalized trade-off (1.0 = best in class; ideal is bottom-left):");
    let dir = results_dir();
    let f = std::fs::File::create(dir.join("fig6_tradeoff.csv"))?;
    let mut csv = Writer::new(
        std::io::BufWriter::new(f),
        &["policy", "cold_vs_best", "carbon_vs_best"],
    )?;
    for (name, cold, carbon) in cmp.tradeoff_coordinates() {
        println!("  {name:<16} cold×{cold:<8.2} keepalive-carbon×{carbon:.2}");
        csv.row(&[name, format!("{cold:.4}"), format!("{carbon:.4}")])?;
    }

    println!("\nFig 7 — composite metrics (lower is better):");
    println!("  best LCP: {:?}   best IRI: {:?}", cmp.best_lcp(), cmp.best_iri());

    // Paper-shape checks: LACE-RL beats Huawei on both cold starts and
    // keep-alive carbon, and wins both composites.
    let lace = &cmp.get("lace-rl").unwrap().metrics;
    let huawei = &cmp.get("huawei-60s").unwrap().metrics;
    println!(
        "\nvs Huawei static: cold starts {:.1}% lower, keep-alive carbon {:.1}% lower",
        100.0 * (1.0 - lace.cold_starts as f64 / huawei.cold_starts as f64),
        100.0 * (1.0 - lace.keepalive_carbon_g / huawei.keepalive_carbon_g),
    );
    Ok(())
}

/// Run the standard five-policy comparison (Oracle excluded here; it gets
/// its own Table III experiment).
pub fn compare(
    trace: &crate::trace::model::Trace,
    w: &workload::Workload,
    lambda: f64,
) -> anyhow::Result<Comparison> {
    let mut cmp = Comparison::new("general");
    let mut lat = LatencyMin;
    cmp.add("latency-min", workload::evaluate(trace, &w.ci, &w.energy, &mut lat, lambda, false));
    let mut car = CarbonMin;
    cmp.add("carbon-min", workload::evaluate(trace, &w.ci, &w.energy, &mut car, lambda, false));
    let mut hw = FixedTimeout::huawei();
    cmp.add("huawei-60s", workload::evaluate(trace, &w.ci, &w.energy, &mut hw, lambda, false));
    let mut dpso = Dpso::new(DpsoConfig::default());
    cmp.add("dpso-ecolife", workload::evaluate(trace, &w.ci, &w.energy, &mut dpso, lambda, false));
    let mut lace = workload::lace_rl_policy()?;
    cmp.add("lace-rl", workload::evaluate(trace, &w.ci, &w.energy, &mut lace, lambda, false));
    Ok(cmp)
}
