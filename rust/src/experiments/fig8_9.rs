//! Figs. 8–9 — Long-tailed workload: the high-cold-start-latency subset
//! ("Custom" runtimes, heavy initialization). Same metric suite as
//! Figs. 5–7.

use crate::experiments::fig5_7::compare;
use crate::experiments::{results_dir, workload};
use crate::util::csv::Writer;

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    let w = workload::build(seed, quick);
    println!(
        "Long-tailed workload: {} invocations (cold start ≥ {:.0}s functions; {:.0}% of General)",
        w.long_tailed.len(),
        workload::LONG_TAIL_THRESH_S,
        100.0 * w.long_tailed.len() as f64 / w.general.len().max(1) as f64
    );
    let cmp = compare(&w.long_tailed, &w, 0.5, "long-tailed")?;

    println!("\nFig 8 — absolute metrics:");
    print!("{}", cmp.table());

    println!("Fig 9 — normalized trade-off:");
    let dir = results_dir();
    let f = std::fs::File::create(dir.join("fig9_tradeoff.csv"))?;
    let mut csv = Writer::new(
        std::io::BufWriter::new(f),
        &["policy", "cold_vs_best", "carbon_vs_best"],
    )?;
    for (name, cold, carbon) in cmp.tradeoff_coordinates() {
        println!("  {name:<16} cold×{cold:<8.2} keepalive-carbon×{carbon:.2}");
        csv.row(&[name, format!("{cold:.4}"), format!("{carbon:.4}")])?;
    }
    println!("\ncomposites — best LCP: {:?}   best IRI: {:?}", cmp.best_lcp(), cmp.best_iri());
    Ok(())
}
