//! Experiment harness: one module per paper figure/table (DESIGN.md §5).
//!
//! Every experiment prints the same rows/series the paper reports and
//! (where useful) writes CSV series under `results/` for plotting.
//! `lace-rl experiment <id>` dispatches here; `lace-rl experiment all`
//! runs the full evaluation.

pub mod ablation;
pub mod cost;
pub mod fig1;
pub mod fig10;
pub mod fig2;
pub mod fig3;
pub mod fig5_7;
pub mod fig8_9;
pub mod resilience;
pub mod table2;
pub mod table3;
pub mod workload;

use anyhow::Result;

/// All experiment ids in paper order (plus the ablation and resilience
/// suites).
pub const ALL: [&str; 14] = [
    "fig1", "fig2", "fig3", "table2", "fig5", "fig6", "fig7", "fig8", "fig9",
    "table3", "cost", "fig10", "ablation", "resilience",
];

/// Dispatch an experiment by id. `seed` pins the synthetic workload;
/// `quick` shrinks the workload for smoke runs. When an [`crate::obs`]
/// sink is installed (`--obs`), a telemetry summary table prints after
/// the experiment completes.
pub fn run(id: &str, seed: u64, quick: bool) -> Result<()> {
    let r = dispatch(id, seed, quick);
    if let Some(sink) = crate::obs::sink() {
        let summary = sink.summary();
        if !summary.is_empty() {
            print!("\n{summary}");
        }
    }
    r
}

fn dispatch(id: &str, seed: u64, quick: bool) -> Result<()> {
    match id {
        "fig1" => fig1::run(seed, quick),
        "fig2" => fig2::run(seed, quick),
        "fig3" => fig3::run(seed, quick),
        "table2" => table2::run(),
        "fig5" | "fig6" | "fig7" => fig5_7::run(seed, quick),
        "fig8" | "fig9" => fig8_9::run(seed, quick),
        "table3" => table3::run(seed, quick),
        "cost" => cost::run(seed, quick),
        "fig10" | "fig10a" | "fig10b" => fig10::run(seed, quick),
        "ablation" => ablation::run(seed, quick),
        "resilience" => resilience::run(seed, quick),
        "all" => {
            // Per-experiment + total wall-clock: the number EXPERIMENTS.md
            // §Perf tracks across optimization iterations.
            let t_all = std::time::Instant::now();
            for e in [
                "fig1", "fig2", "fig3", "table2", "fig5", "fig8", "table3", "cost",
                "fig10", "ablation", "resilience",
            ] {
                println!("\n================ experiment {e} ================");
                let t0 = std::time::Instant::now();
                // dispatch, not run: counters are cumulative, so `all`
                // prints one telemetry summary at the end, not ten.
                dispatch(e, seed, quick)?;
                println!("[{e} done in {:.2}s]", t0.elapsed().as_secs_f64());
            }
            println!(
                "\n================ experiment all: {:.2}s total ================",
                t_all.elapsed().as_secs_f64()
            );
            Ok(())
        }
        other => anyhow::bail!("unknown experiment '{other}'; known: {ALL:?} or 'all'"),
    }
}

/// Results directory (created on demand).
pub fn results_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from("results");
    let _ = std::fs::create_dir_all(&d);
    d
}
