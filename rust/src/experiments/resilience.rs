//! Resilience experiment — degraded-mode behavior under fault injection.
//!
//! Sweeps fault intensity × policy over the General workload using the
//! canned [`FaultPlan`] (spawn-failure, carbon-outage, decision-delay, and
//! a driver stall scaled by intensity; see DESIGN.md §10) and reports how
//! much each policy's latency and carbon degrade relative to its own
//! fault-free baseline, alongside the raw degraded-mode counters.
//!
//! Same plan + seed ⇒ bit-identical rows (the chaos determinism invariant,
//! property-tested in `rust/tests/property_chaos.rs`); intensity 0.0 is an
//! empty plan and reproduces the fault-free run exactly.

use std::sync::Arc;

use crate::chaos::{ChaosInjector, FaultPlan};
use crate::experiments::{results_dir, workload};
use crate::policy::{CarbonMin, FixedTimeout, LatencyMin};
use crate::simulator::engine::SimConfig;
use crate::simulator::metrics::SimMetrics;
use crate::simulator::parallel::{BoxedPolicy, SweepCell, SweepRunner};
use crate::util::csv::Writer;

/// Canned-plan fault intensities swept (0 = fault-free baseline).
pub const INTENSITIES: [f64; 3] = [0.0, 0.5, 1.0];

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    let w = workload::build(seed, quick);
    let t0 = w.general.invocations.first().map(|i| i.t).unwrap_or(0.0);
    let t1 = w.general.invocations.last().map(|i| i.t).unwrap_or(t0);
    println!(
        "Resilience: {} invocations over [{t0:.0}s, {t1:.0}s], fault intensities {INTENSITIES:?}",
        w.general.len(),
    );

    let params = workload::lace_rl_params()?;
    let mut cells = Vec::new();
    for &x in &INTENSITIES {
        let plan = FaultPlan::canned(seed, t0, t1, x);
        let cfg = SimConfig {
            chaos: Some(Arc::new(ChaosInjector::new(plan))),
            ..SimConfig::default()
        };
        cells.push(SweepCell::new(format!("huawei-60s@{x:.1}"), cfg.clone(), || {
            Box::new(FixedTimeout::huawei()) as BoxedPolicy
        }));
        cells.push(SweepCell::new(format!("latency-min@{x:.1}"), cfg.clone(), || {
            Box::new(LatencyMin) as BoxedPolicy
        }));
        cells.push(SweepCell::new(format!("carbon-min@{x:.1}"), cfg.clone(), || {
            Box::new(CarbonMin) as BoxedPolicy
        }));
        let p = params.clone();
        cells.push(SweepCell::new(format!("lace-rl@{x:.1}"), cfg, move || {
            Box::new(workload::lace_rl_from_params(&p)) as BoxedPolicy
        }));
    }

    let runner = SweepRunner::new(&w.general, &w.ci, w.energy.clone());
    let outcomes = runner.run(cells);

    // Baseline (intensity 0.0) metrics per policy for the delta columns.
    let baseline = |policy: &str| -> Option<&SimMetrics> {
        let want = format!("{policy}@0.0");
        outcomes.iter().find(|o| o.label == want).map(|o| &o.result.metrics)
    };

    let dir = results_dir();
    let f = std::fs::File::create(dir.join("resilience.csv"))?;
    let mut csv = Writer::new(
        std::io::BufWriter::new(f),
        &[
            "policy",
            "intensity",
            "cold_starts",
            "avg_latency_s",
            "total_carbon_g",
            "latency_delta_pct",
            "carbon_delta_pct",
            "spawn_retries",
            "retry_delay_s",
            "stale_ci_decisions",
            "degraded_decisions",
        ],
    )?;

    println!(
        "\n{:<22} {:>8} {:>10} {:>10} {:>9} {:>9} {:>9} {:>8} {:>8}",
        "cell", "cold", "latency", "carbon", "Δlat%", "Δcarb%", "retries", "stale", "degr"
    );
    for o in &outcomes {
        let (policy, intensity) = o
            .label
            .rsplit_once('@')
            .ok_or_else(|| anyhow::anyhow!("bad cell label '{}'", o.label))?;
        let m = &o.result.metrics;
        let (dlat, dcarb) = match baseline(policy) {
            Some(b) if b.avg_latency_s() > 0.0 && b.total_carbon_g() > 0.0 => (
                100.0 * (m.avg_latency_s() / b.avg_latency_s() - 1.0),
                100.0 * (m.total_carbon_g() / b.total_carbon_g() - 1.0),
            ),
            _ => (0.0, 0.0),
        };
        println!(
            "{:<22} {:>8} {:>10.4} {:>10.3} {:>8.2}% {:>8.2}% {:>8} {:>8} {:>8}",
            o.label,
            m.cold_starts,
            m.avg_latency_s(),
            m.total_carbon_g(),
            dlat,
            dcarb,
            m.chaos.spawn_retries,
            m.chaos.stale_ci_decisions,
            m.chaos.degraded_decisions,
        );
        csv.row(&[
            policy.to_string(),
            intensity.to_string(),
            m.cold_starts.to_string(),
            format!("{:.6}", m.avg_latency_s()),
            format!("{:.6}", m.total_carbon_g()),
            format!("{dlat:.3}"),
            format!("{dcarb:.3}"),
            m.chaos.spawn_retries.to_string(),
            format!("{:.4}", m.chaos.retry_delay_s),
            m.chaos.stale_ci_decisions.to_string(),
            m.chaos.degraded_decisions.to_string(),
        ])?;
    }

    // Sanity anchors: empty plans inject nothing; full intensity injects
    // spawn retries on every policy (the window covers 40% of the trace).
    for o in &outcomes {
        if o.label.ends_with("@0.0") {
            anyhow::ensure!(
                !o.result.metrics.chaos.any(),
                "intensity 0 cell '{}' recorded chaos events",
                o.label
            );
        }
        if o.label.ends_with("@1.0") {
            anyhow::ensure!(
                o.result.metrics.chaos.any(),
                "intensity 1 cell '{}' recorded no chaos events",
                o.label
            );
        }
    }
    println!("\nwrote {}", dir.join("resilience.csv").display());
    Ok(())
}
