//! Table II — FunctionBench energy calibration: regenerates the table from
//! the embedded calibration dataset and validates the derived constants
//! (λ_idle range, the cold-duration→energy correlation, and the
//! conservativeness of the simulation's λ_idle = 0.2).

use crate::energy::calibration::{
    cold_duration_energy_correlation, lambda_idle_stats, FUNCTIONBENCH,
    SIMULATION_LAMBDA_IDLE,
};

pub fn run() -> anyhow::Result<()> {
    println!("Table II — energy profiling of serverless pods (cold / compute / keep-alive):\n");
    println!(
        "{:<22} {:>8} {:>10} {:>10} {:>9} {:>10} {:>11} {:>10} {:>10} {:>7}",
        "function", "mem(MB)", "cold(ms)", "comp(ms)", "cold(J)", "comp(J)",
        "ka-1min(J)", "comp(W)", "ka(W)", "λ_idle"
    );
    for b in &FUNCTIONBENCH {
        println!(
            "{:<22} {:>8.0} {:>10.2} {:>10.2} {:>9.2} {:>10.2} {:>11.2} {:>10.2} {:>10.2} {:>7.2}",
            b.name,
            b.mem_mb,
            b.cold_start_ms,
            b.compute_ms,
            b.cold_active_j,
            b.compute_active_j,
            b.keepalive_1min_j,
            b.compute_power_w,
            b.keepalive_power_w,
            b.lambda_idle
        );
    }

    let (min, max, mean) = lambda_idle_stats();
    println!("\nλ_idle measured range: {min:.2}–{max:.2} (mean {mean:.2})");
    println!("simulation λ_idle = {SIMULATION_LAMBDA_IDLE} (conservative: ≤ measured minimum)");
    anyhow::ensure!(SIMULATION_LAMBDA_IDLE <= min);

    let r = cold_duration_energy_correlation();
    println!("cold-start duration ↔ cold-start energy Pearson r = {r:.3}");
    anyhow::ensure!(r > 0.8, "duration should predict energy (paper §IV-A1)");

    let outliers: Vec<&str> = FUNCTIONBENCH
        .iter()
        .filter(|b| b.cold_start_ms > 2000.0)
        .map(|b| b.name)
        .collect();
    println!("long-initialization outliers (heavy deps/model loading): {outliers:?}");
    Ok(())
}
