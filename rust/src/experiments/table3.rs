//! Table III — LACE-RL vs the Oracle policy over a two-hour trace slice,
//! on the General and Long-tailed workloads: keep-alive carbon and
//! cold-start count degradation relative to perfect future knowledge.

use crate::experiments::workload;
use crate::policy::Oracle;
use crate::simulator::engine::SimConfig;
use crate::simulator::parallel::{BoxedPolicy, SweepCell, SweepRunner};
use crate::trace::model::Trace;

pub fn run(seed: u64, quick: bool) -> anyhow::Result<()> {
    let w = workload::build(seed, quick);
    let slice_s = 2.0 * 3600.0;
    let general = time_slice(&w.general, slice_s);
    let long_tailed = time_slice(&w.long_tailed, slice_s);
    let params = workload::lace_rl_params()?;

    // All four (case × policy) runs as one parallel sweep; the Oracle cells
    // enable the clairvoyant next-arrival gap, LACE-RL runs blind.
    let oracle_cfg = SimConfig { provide_oracle_gap: true, ..SimConfig::default() };
    let mut cells = Vec::new();
    for (case, trace) in [("General", &general), ("Long-tailed", &long_tailed)] {
        cells.push(
            SweepCell::new(format!("{case}/oracle"), oracle_cfg.clone(), || {
                Box::new(Oracle) as BoxedPolicy
            })
            .with_trace(trace),
        );
        let p = params.clone();
        cells.push(
            SweepCell::new(format!("{case}/lace-rl"), SimConfig::default(), move || {
                Box::new(workload::lace_rl_from_params(&p)) as BoxedPolicy
            })
            .with_trace(trace),
        );
    }
    let outcomes = SweepRunner::new(&w.general, &w.ci, w.energy.clone()).run(cells);

    println!("Table III — LACE-RL vs Oracle (two-hour slice):\n");
    println!(
        "{:<12} {:<28} {:>10} {:>10} {:>12}",
        "case", "metric", "Oracle", "LACE-RL", "degradation"
    );
    for (i, case) in ["General", "Long-tailed"].into_iter().enumerate() {
        let om = &outcomes[2 * i].result.metrics;
        let lm = &outcomes[2 * i + 1].result.metrics;

        let deg = |o: f64, l: f64| {
            if o <= 0.0 { 0.0 } else { 100.0 * (l - o) / o }
        };
        println!(
            "{:<12} {:<28} {:>10.3} {:>10.3} {:>11.3}%",
            case,
            "Keep-alive Carbon (gCO2)",
            om.keepalive_carbon_g,
            lm.keepalive_carbon_g,
            deg(om.keepalive_carbon_g, lm.keepalive_carbon_g)
        );
        println!(
            "{:<12} {:<28} {:>10} {:>10} {:>11.3}%",
            case,
            "Cold Start Count",
            om.cold_starts,
            lm.cold_starts,
            deg(om.cold_starts as f64, lm.cold_starts as f64)
        );
        // The objective both policies actually optimize (Eq. 5 aggregate):
        // under bursty concurrency the per-decision Oracle is only optimal
        // per pod, so LACE-RL may beat it on one axis while paying on the
        // other — the blended view is the apples-to-apples gap.
        let blended = |m: &crate::simulator::metrics::SimMetrics| {
            crate::policy::blended_cost(0.5, m.cold_latency_s, m.keepalive_carbon_g)
        };
        println!(
            "{:<12} {:<28} {:>10.1} {:>10.1} {:>11.3}%",
            case,
            "Blended objective (Eq. 5)",
            blended(om),
            blended(lm),
            deg(blended(om), blended(lm))
        );
    }
    println!(
        "\n(paper reports +6.2%/+7.2% General and +9.0%/+11.2% Long-tailed degradations.\n\
         Our Oracle is the paper's *per-decision* clairvoyant: optimal for each pod in\n\
         isolation but blind to pool-level effects under bursty concurrency — it trades\n\
         cold starts for carbon differently than the pool-aware learned policy, which\n\
         here even beats it on the blended Eq. 5 objective. See EXPERIMENTS.md.)"
    );
    Ok(())
}

/// First `span_s` seconds of a trace.
fn time_slice(trace: &Trace, span_s: f64) -> Trace {
    let t0 = trace.invocations.first().map(|i| i.t).unwrap_or(0.0);
    Trace::new(
        trace.functions.clone(),
        trace
            .invocations
            .iter()
            .take_while(|i| i.t - t0 <= span_s)
            .copied()
            .collect(),
    )
}
