//! Shared workload construction for the evaluation experiments.
//!
//! Mirrors the paper's setup (§IV-A): one paper-scale synthetic trace
//! (≈1M invocations / 400 functions / 1 day), split 80/10/10; the General
//! workload is the test split, the Long-tailed workload its high-cold-
//! latency subset; the carbon trace is the solar-heavy region archetype.

use crate::carbon::intensity::CarbonTrace;
use crate::carbon::synth::{synth_region, Region};
use crate::energy::model::EnergyModel;
use crate::policy::KeepAlivePolicy;
use crate::simulator::engine::{SimConfig, SimResult};
use crate::simulator::metrics::SimMetrics;
use crate::simulator::sharded::ShardedSimulator;
use crate::trace::model::Trace;
use crate::trace::synth::{SynthConfig, TraceGenerator};

/// Cold-start latency threshold (s) defining the Long-tailed subset.
pub const LONG_TAIL_THRESH_S: f64 = 1.0;

/// The evaluation workload bundle.
pub struct Workload {
    pub train: Trace,
    pub valid: Trace,
    pub general: Trace,
    pub long_tailed: Trace,
    pub ci: CarbonTrace,
    pub energy: EnergyModel,
}

/// Paper-scale config (quick=false: calibrated reuse-gap rates over a full
/// day, ≈3.5M invocations) or a CI-friendly shrink (quick=true: same gap
/// *calibration* over 2 h, ≈150k invocations — rates stay natural so the
/// gap quantiles hold, only the horizon shrinks).
pub fn synth_config(seed: u64, quick: bool) -> SynthConfig {
    if quick {
        SynthConfig {
            n_functions: 150,
            duration_s: 7_200.0, // 2h
            target_invocations: 0,
            sparse_frac: 0.8, // keep enough hot traffic at smoke scale
            seed,
            ..SynthConfig::default()
        }
    } else {
        SynthConfig { seed, ..SynthConfig::default() }
    }
}

/// Build the full evaluation bundle.
pub fn build(seed: u64, quick: bool) -> Workload {
    let trace = TraceGenerator::new(synth_config(seed, quick)).generate();
    let (train, valid, general) = trace.split(0.8, 0.1);
    let long_tailed = general.long_tail_subset(LONG_TAIL_THRESH_S);
    let ci = synth_region(Region::SolarHeavy, 2, seed);
    Workload {
        train,
        valid,
        general,
        long_tailed,
        ci,
        energy: EnergyModel::default(),
    }
}

/// Run one policy over a trace with the standard evaluation config and
/// return the full [`SimResult`] (metrics, tracked latencies, and — when
/// telemetry collection is on — the merged `obs` series). Single runs are
/// function-sharded across the machine's cores (bit-identical to
/// sequential; `LACE_SIM_SHARDS=1` forces sequential).
pub fn evaluate_result(
    trace: &Trace,
    ci: &CarbonTrace,
    energy: &EnergyModel,
    policy: &mut dyn KeepAlivePolicy,
    lambda_carbon: f64,
    oracle_gap: bool,
) -> SimResult {
    let cfg = SimConfig {
        lambda_carbon,
        provide_oracle_gap: oracle_gap,
        ..SimConfig::default()
    };
    ShardedSimulator::new(trace, ci, energy.clone(), cfg).run(policy)
}

/// [`evaluate_result`] reduced to its metrics (the common case).
pub fn evaluate(
    trace: &Trace,
    ci: &CarbonTrace,
    energy: &EnergyModel,
    policy: &mut dyn KeepAlivePolicy,
    lambda_carbon: f64,
    oracle_gap: bool,
) -> SimMetrics {
    evaluate_result(trace, ci, energy, policy, lambda_carbon, oracle_gap).metrics
}

/// Load the trained Q-network weights (or init weights when untrained)
/// once; sweep-cell factories clone these instead of re-reading artifacts
/// from disk per cell.
pub fn lace_rl_params() -> anyhow::Result<crate::rl::qnet::QNetParams> {
    let artifacts =
        crate::runtime::ArtifactSet::open(&crate::runtime::artifacts::default_dir())?;
    artifacts.best_params()
}

/// Load LACE-RL with trained weights (or init weights when untrained) on
/// the native fast path.
pub fn lace_rl_policy() -> anyhow::Result<
    crate::policy::lace_rl::LaceRlPolicy<crate::policy::native_mlp::NativeMlp>,
> {
    Ok(lace_rl_from_params(&lace_rl_params()?))
}

/// Build a fresh LACE-RL instance from already-loaded weights.
pub fn lace_rl_from_params(
    params: &crate::rl::qnet::QNetParams,
) -> crate::policy::lace_rl::LaceRlPolicy<crate::policy::native_mlp::NativeMlp> {
    crate::policy::lace_rl::LaceRlPolicy::new(
        crate::policy::native_mlp::NativeMlp::new(params.clone()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::fixed::FixedTimeout;

    #[test]
    fn bundle_splits_consistently() {
        let cfg = SynthConfig {
            n_functions: 30,
            duration_s: 1800.0,
            target_invocations: 10_000,
            seed: 3,
            ..SynthConfig::default()
        };
        let trace = TraceGenerator::new(cfg).generate();
        let (tr, va, te) = trace.split(0.8, 0.1);
        assert_eq!(tr.len() + va.len() + te.len(), trace.len());
        let lt = te.long_tail_subset(LONG_TAIL_THRESH_S);
        assert!(lt.len() <= te.len());
    }

    #[test]
    fn evaluate_runs_fixed_policy() {
        let w = {
            let trace = TraceGenerator::new(SynthConfig {
                n_functions: 20,
                duration_s: 900.0,
                target_invocations: 5_000,
                seed: 4,
                ..SynthConfig::default()
            })
            .generate();
            trace
        };
        let ci = synth_region(Region::SolarHeavy, 1, 4);
        let m = evaluate(&w, &ci, &EnergyModel::default(), &mut FixedTimeout::huawei(), 0.5, false);
        assert_eq!(m.invocations as usize, w.len());
        assert!(m.total_carbon_g() > 0.0);
    }
}
