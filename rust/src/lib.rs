//! # LACE-RL — Latency-Aware, Carbon-Efficient serverless keep-alive management
//!
//! Reproduction of *"Green or Fast? Learning to Balance Cold Starts and Idle
//! Carbon in Serverless Computing"* (CCGrid 2026) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1 / L2 (build-time Python)** — the DQN Q-network (Pallas fused-MLP
//!   kernel + jax train step) AOT-lowered to HLO text under `artifacts/`.
//! * **L3 (this crate)** — everything that runs: trace + carbon substrates,
//!   energy model, event-driven serverless cluster simulator, the keep-alive
//!   policies (Huawei-static, Latency-Min, Carbon-Min, DPSO/EcoLife, Oracle,
//!   LACE-RL), the DQN training loop driving either the AOT train step via
//!   PJRT or the pure-Rust batched gradient engine (`--backend native`),
//!   a threaded online coordinator, and the experiment harness regenerating
//!   every figure and table of the paper.
//!
//! Python never executes on the decision path: after the AOT step
//! (`python/compile/aot.py`) the binary is self-contained.
//!
//! ## Crate map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | from-scratch substrates: PRNG, distributions, JSON, TOML-subset config, CSV, CLI, stats, mini property-testing, bench timing |
//! | [`trace`] | Huawei-trace model, synthetic generator calibrated to the paper's published marginals, CSV loader |
//! | [`carbon`] | grid carbon-intensity traces (synthetic duck-curve archetypes + loader) |
//! | [`energy`] | the paper's energy/carbon accounting model (Eq. 1–4) + FunctionBench Table II calibration |
//! | [`simulator`] | event-driven cluster: pods, warm pool, keep-alive expiry, metrics |
//! | [`simulator::parallel`] | sweep harness: policy×config cells across scoped threads, deterministic order, bit-identical to sequential |
//! | [`simulator::sharded`] | function-sharded single-run parallelism: one trace split across cores via `KeepAlivePolicy::fork`, bit-identical to sequential |
//! | [`policy`] | the six keep-alive policies behind one trait |
//! | [`rl`] | state encoder, replay buffer, ε-greedy agent, backend-agnostic DQN trainer, weight I/O |
//! | [`rl::native_train`] | pure-Rust batched train step: GEMM forward/backward + in-place Adam, zero allocations per gradient step |
//! | [`runtime`] | PJRT client wrapper: load HLO text artifacts, compile, execute; `PjrtBackend` gradient engine |
//! | [`util::gemm`] | shared 4-wide register-tiled f32 GEMM kernels behind both the inference and training hot paths |
//! | [`coordinator`] | threaded online control plane: workload driver → router → pod lifecycle |
//! | [`experiments`] | one harness per paper figure/table |
//! | [`metrics`] | composite metrics (LCP, IRI) and report formatting |
//! | [`obs`] | structured telemetry: counters, histograms, spans, JSONL export (no-op until a sink is installed) |
//! | [`chaos`] | deterministic fault injection + recovery: seeded `FaultPlan` DSL, injection hooks in both stacks, degraded-mode accounting |

pub mod carbon;
pub mod chaos;
pub mod coordinator;
pub mod energy;
pub mod experiments;
pub mod metrics;
pub mod obs;
pub mod policy;
pub mod rl;
pub mod runtime;
pub mod simulator;
pub mod trace;
pub mod util;

/// Keep-alive action set (seconds), paper §IV-A4: roughly the 10th/50th/75th/
/// 90th percentiles of reuse intervals plus Huawei's production 60 s timeout.
pub const KEEP_ALIVE_ACTIONS: [f64; 5] = [1.0, 5.0, 10.0, 30.0, 60.0];

/// Huawei's static production keep-alive timeout (seconds).
pub const HUAWEI_TIMEOUT_S: f64 = 60.0;

/// Fixed network latency offset (seconds), profiled via AWS CloudPing in the
/// paper (footnote 3); constant in the single-site setting.
pub const NETWORK_LATENCY_S: f64 = 0.025;
