//! LACE-RL command-line launcher.
//!
//! ```text
//! lace-rl gen-trace   [--out trace.csv] [--seed 7] [--functions 400] ...
//! lace-rl train       [--episodes 30] [--lambda 0.5] [--backend native|pjrt] [--quick]
//! lace-rl simulate    [--policy lace-rl|huawei|latency-min|carbon-min|dpso|oracle]
//! lace-rl experiment  <fig1|fig2|fig3|table2|fig5|fig6|fig7|fig8|fig9|table3|cost|fig10|ablation|resilience|all>
//! lace-rl serve       [--policy ...] [--speedup 0] — online coordinator replay
//! lace-rl chaos       [--intensity 1.0] [--plan FILE] — serve under fault injection
//! lace-rl selftest    — PJRT artifact round-trip check
//! ```

use std::sync::Arc;

use anyhow::Result;
use lace_rl::chaos::{ChaosInjector, FaultPlan};
use lace_rl::coordinator::driver::Pace;
use lace_rl::coordinator::server::ServeReport;
use lace_rl::coordinator::{CoordinatorServer, RouterConfig};
use lace_rl::experiments::{self, workload};
use lace_rl::policy::dpso::DpsoConfig;
use lace_rl::policy::{CarbonMin, Dpso, FixedTimeout, KeepAlivePolicy, LatencyMin, Oracle};
use lace_rl::rl::trainer::{self, TrainerConfig};
use lace_rl::runtime::{artifacts, ArtifactSet, PjrtRuntime, QNetInfer};
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if args.flag("obs") {
        let sink = lace_rl::obs::install_jsonl(experiments::results_dir().join("obs"));
        eprintln!("[obs] telemetry enabled -> {}", sink.dir().display());
    }
    let result = match args.subcommand.as_deref() {
        Some("gen-trace") => cmd_gen_trace(&args),
        Some("train") => cmd_train(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("serve") => cmd_serve(&args),
        Some("chaos") => cmd_chaos(&args),
        Some("selftest") => cmd_selftest(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "LACE-RL — latency-aware, carbon-efficient serverless keep-alive management\n\
         \n\
         USAGE: lace-rl <subcommand> [options]\n\
         \n\
         SUBCOMMANDS:\n\
           gen-trace    generate a synthetic Huawei-like trace CSV\n\
           train        train the DQN (--backend native|pjrt; native needs no artifacts)\n\
           simulate     run one policy over the test workload\n\
           experiment   regenerate a paper figure/table (or 'all')\n\
           serve        replay the workload through the online coordinator\n\
           chaos        serve under a fault plan and report degraded-mode accounting\n\
                        (--intensity X canned plan, or --plan FILE; --save-plan FILE)\n\
           selftest     verify the PJRT artifact round trip\n\
         \n\
         COMMON OPTIONS:\n\
           --seed N          workload seed (default 7)\n\
           --quick           shrunk workload for smoke runs\n\
           --policy NAME     lace-rl|huawei|latency-min|carbon-min|dpso|oracle\n\
           --lambda X        carbon trade-off weight in [0,1] (default 0.5)\n\
           --artifacts DIR   artifact directory (default ./artifacts)\n\
           --backend NAME    train backend: pjrt (default) or native (pure Rust,\n\
                             zero-alloc gradient steps, no artifacts required)\n\
           --obs             stream structured telemetry to results/obs/ as JSONL\n\
                             (pass it last: it is a bare flag, not --key value)"
    );
}

fn seed_of(args: &Args) -> u64 {
    args.u64_or("seed", 7)
}

fn cmd_gen_trace(args: &Args) -> Result<()> {
    let cfg = SynthConfig {
        n_functions: args.usize_or("functions", 400),
        duration_s: args.f64_or("duration", 86_400.0),
        // 0 = natural calibrated rates (paper-scale); >0 rescales.
        target_invocations: args.usize_or("invocations", 0),
        seed: seed_of(args),
        ..SynthConfig::default()
    };
    let trace = TraceGenerator::new(cfg).generate();
    let out = args.str_or("out", "trace.csv");
    lace_rl::trace::huawei::save_csv(&trace, out)?;
    println!(
        "wrote {} invocations / {} functions to {out}",
        trace.len(),
        trace.functions.len()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let w = workload::build(seed_of(args), quick);
    let backend: lace_rl::rl::BackendKind = args.str_or("backend", "pjrt").parse()?;
    let cfg = TrainerConfig {
        episodes: args.usize_or("episodes", if quick { 12 } else { 30 }),
        steps_per_episode: args.usize_or("steps", 800),
        lambda_carbon: args.opt("lambda").and_then(|s| s.parse().ok()),
        seed: seed_of(args),
        backend,
        ..TrainerConfig::default()
    };
    println!(
        "training on {} invocations ({} functions); backend={backend}",
        w.train.len(),
        w.train.functions.len(),
    );
    let t0 = std::time::Instant::now();
    let default_dir = artifacts::default_dir();
    let report = match ArtifactSet::open(args.str_or("artifacts", &default_dir)) {
        Ok(artifacts) => {
            // Artifacts present: either backend starts from the compiled
            // init params and the weights land in the artifact dir.
            let runtime = PjrtRuntime::cpu()?;
            trainer::train_and_save(&artifacts, &runtime, &w.train, &w.ci, &w.energy, &cfg)?
        }
        Err(e) if backend == lace_rl::rl::BackendKind::Native => {
            // No artifacts needed for the native backend: He-uniform init,
            // weights saved next to the CWD.
            println!("(artifacts unavailable: {e:#}; native backend trains from scratch)");
            let report = trainer::train_native(&w.train, &w.ci, &w.energy, &cfg)?;
            let out = args.str_or("out", "trained_weights.json");
            lace_rl::rl::weights::save_params(out, &report.params)?;
            println!("[train] saved weights to {out}");
            report
        }
        Err(e) => return Err(e),
    };
    println!(
        "trained {} episodes / {} gradient steps in {:.1}s ({:.1}s/episode)",
        report.episodes.len(),
        report.total_steps,
        t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64() / report.episodes.len().max(1) as f64
    );
    print_obs_summary();
    Ok(())
}

fn build_policy(name: &str) -> Result<Box<dyn KeepAlivePolicy>> {
    if let Some(rest) = name.strip_prefix("fixed-") {
        // Refreshing fixed timeout at an arbitrary grid point, e.g. fixed-60.
        let secs: f64 = rest.parse().map_err(|_| anyhow::anyhow!("bad fixed-<secs>"))?;
        return Ok(Box::new(FixedTimeout::new(secs)));
    }
    Ok(match name {
        "huawei" => Box::new(FixedTimeout::huawei()),
        "latency-min" => Box::new(LatencyMin),
        "carbon-min" => Box::new(CarbonMin),
        "dpso" => Box::new(Dpso::new(DpsoConfig::default())),
        "oracle" => Box::new(Oracle),
        "lace-rl" => Box::new(workload::lace_rl_policy()?),
        other => anyhow::bail!("unknown policy '{other}'"),
    })
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let w = workload::build(seed_of(args), args.flag("quick"));
    let name = args.str_or("policy", "lace-rl");
    let lambda = args.f64_or("lambda", 0.5);
    let trace = if args.flag("long-tailed") { &w.long_tailed } else { &w.general };
    let mut policy = build_policy(name)?;
    let r = workload::evaluate_result(
        trace,
        &w.ci,
        &w.energy,
        policy.as_mut(),
        lambda,
        name == "oracle",
    );
    println!("{}", r.metrics.summary_row(name));
    if let Some(obs) = &r.obs {
        lace_rl::obs::emit_sim(&format!("simulate_{name}"), obs);
    }
    print_obs_summary();
    Ok(())
}

/// Print the sink's cumulative summary table, if telemetry is on and
/// anything was recorded (experiments print their own via the harness).
fn print_obs_summary() {
    if let Some(sink) = lace_rl::obs::sink() {
        let summary = sink.summary();
        if !summary.is_empty() {
            print!("\n{summary}");
        }
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    experiments::run(id, seed_of(args), args.flag("quick"))
}

/// Replay the General workload through the coordinator with the named
/// policy. The server is generic over the policy type; route through the
/// concrete types (trait objects are not Send+'static-friendly here).
fn serve_with(
    name: &str,
    w: &workload::Workload,
    cfg: RouterConfig,
    pace: Pace,
) -> Result<ServeReport> {
    Ok(match name {
        "huawei" => {
            CoordinatorServer::run(&w.general, FixedTimeout::huawei(), w.ci.clone(), w.energy.clone(), cfg, pace, 1024)?.0
        }
        "latency-min" => {
            CoordinatorServer::run(&w.general, LatencyMin, w.ci.clone(), w.energy.clone(), cfg, pace, 1024)?.0
        }
        "carbon-min" => {
            CoordinatorServer::run(&w.general, CarbonMin, w.ci.clone(), w.energy.clone(), cfg, pace, 1024)?.0
        }
        "dpso" => {
            CoordinatorServer::run(&w.general, Dpso::new(DpsoConfig::default()), w.ci.clone(), w.energy.clone(), cfg, pace, 1024)?.0
        }
        "lace-rl" => {
            CoordinatorServer::run(&w.general, workload::lace_rl_policy()?, w.ci.clone(), w.energy.clone(), cfg, pace, 1024)?.0
        }
        other => anyhow::bail!("unknown policy '{other}' for serve"),
    })
}

fn pace_of(args: &Args) -> Pace {
    let speedup = args.f64_or("speedup", 0.0);
    if speedup > 0.0 { Pace::RealTime { speedup } } else { Pace::MaxSpeed }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let w = workload::build(seed_of(args), args.flag("quick"));
    let name = args.str_or("policy", "lace-rl");
    let cfg = RouterConfig {
        lambda_carbon: args.f64_or("lambda", 0.5),
        ..RouterConfig::default()
    };
    let report = serve_with(name, &w, cfg, pace_of(args))?;
    report.print(name);
    print_obs_summary();
    Ok(())
}

fn cmd_chaos(args: &Args) -> Result<()> {
    let seed = seed_of(args);
    let w = workload::build(seed, args.flag("quick"));
    let name = args.str_or("policy", "huawei");
    let plan = match args.opt("plan") {
        Some(path) => FaultPlan::load(path)?,
        None => {
            // Anchor the canned plan to the actual replay span so the
            // fault windows overlap the traffic regardless of --quick.
            let t0 = w.general.invocations.first().map(|i| i.t).unwrap_or(0.0);
            let t1 = w.general.invocations.last().map(|i| i.t).unwrap_or(t0);
            FaultPlan::canned(seed, t0, t1, args.f64_or("intensity", 1.0))
        }
    };
    if let Some(path) = args.opt("save-plan") {
        plan.save(path)?;
        println!("wrote fault plan to {path}");
    }
    println!(
        "fault plan: seed={} faults={} ({})",
        plan.seed,
        plan.faults.len(),
        if plan.is_empty() { "empty — fault-free replay" } else { "active" },
    );
    let cfg = RouterConfig {
        lambda_carbon: args.f64_or("lambda", 0.5),
        chaos: Some(Arc::new(ChaosInjector::new(plan))),
        ..RouterConfig::default()
    };
    let report = serve_with(name, &w, cfg, pace_of(args))?;
    report.print(name);
    print_obs_summary();
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let artifacts = ArtifactSet::open(args.str_or("artifacts", &artifacts::default_dir()))?;
    let runtime = PjrtRuntime::cpu()?;
    println!("platform={} devices={}", runtime.platform(), runtime.device_count());
    let params = artifacts.init_params()?;
    let dims = artifacts.manifest.dims();

    // PJRT Pallas-kernel path vs native Rust forward must agree.
    let exe = runtime.load_hlo_text(artifacts.infer_path(1).to_str().unwrap())?;
    let infer = QNetInfer::new(exe, 1, dims);
    let state: Vec<f32> = (0..dims.0).map(|i| 0.1 * i as f32).collect();
    let q_pjrt = infer.q_values(&params, &state)?;
    let mut native = lace_rl::policy::native_mlp::NativeMlp::new(params.clone());
    let q_native = native.forward(&state).to_vec();
    let max_diff = q_pjrt
        .iter()
        .zip(q_native.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("pjrt (pallas)  q = {q_pjrt:?}");
    println!("native (rust)  q = {q_native:?}");
    println!("max |diff| = {max_diff:.3e}");
    anyhow::ensure!(max_diff < 1e-4, "PJRT and native paths disagree");

    // Train-step executable loads and runs one step.
    let exe = runtime.load_hlo_text(artifacts.train_step_path().to_str().unwrap())?;
    let step = lace_rl::runtime::TrainStep::new(exe, artifacts.manifest.train_batch, dims);
    let b = artifacts.manifest.train_batch;
    let m0 = lace_rl::rl::qnet::QNetParams::zeros(dims);
    let out = step.step(
        &params,
        &params,
        &m0,
        &m0,
        1.0,
        &vec![0.1; b * dims.0],
        &vec![0i32; b],
        &vec![-1.0; b],
        &vec![0.2; b * dims.0],
        &vec![0.0; b],
    )?;
    println!("train step: loss = {:.6}", out.loss);
    anyhow::ensure!(out.loss.is_finite(), "non-finite loss");
    anyhow::ensure!(out.params.max_abs_diff(&params) > 0.0, "train step did not update params");
    println!("selftest OK");
    Ok(())
}
