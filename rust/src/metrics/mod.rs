//! Composite metrics and cross-policy report formatting (Figs. 5–9).
//!
//! The paper folds the two axes of the trade-off into two composite
//! scores (§IV-A6), both computed here via [`SimMetrics`]:
//!
//! * **LCP** (Latency–Carbon Product) — `avg_e2e_latency_s ×
//!   total_carbon_g`, where total carbon is the sum of execution,
//!   keep-alive (idle), and cold-start energy carbon (§II-B, Eqs. 1–4).
//!   Lower is better; a policy only wins LCP by being good on *both*
//!   axes at once.
//! * **IRI** (Inefficiency–Responsiveness Index) — `cold_starts ×
//!   keepalive_carbon_g`: the product of the responsiveness failure
//!   count and the idle-energy waste it was supposed to buy down.
//!   A latency-min policy drives the first factor to its floor but pays
//!   in the second; carbon-min the reverse — IRI punishes both corners.
//!
//! This module formats those numbers: per-workload comparison tables
//! (Figs. 5/8), normalized trade-off coordinates (Figs. 6/9), and the
//! best-composite picks (Figs. 7/9 claims).
#![deny(missing_docs)]

use crate::simulator::metrics::SimMetrics;

/// One policy's results in a comparison.
#[derive(Debug, Clone)]
pub struct PolicyResult {
    /// Policy label as shown in tables (e.g. `lace-rl`, `huawei-60s`).
    pub name: String,
    /// The simulator's aggregate metrics for this policy.
    pub metrics: SimMetrics,
}

/// A multi-policy comparison over one workload.
#[derive(Debug, Clone, Default)]
pub struct Comparison {
    /// Workload label (e.g. `general`, `long-tailed`).
    pub workload: String,
    /// Per-policy rows in insertion order.
    pub results: Vec<PolicyResult>,
}

impl Comparison {
    /// An empty comparison for the named workload.
    pub fn new(workload: &str) -> Self {
        Comparison { workload: workload.to_string(), results: Vec::new() }
    }

    /// Append one policy's metrics row.
    pub fn add(&mut self, name: &str, metrics: SimMetrics) {
        self.results.push(PolicyResult { name: name.to_string(), metrics });
    }

    /// Look up a row by policy name.
    pub fn get(&self, name: &str) -> Option<&PolicyResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Normalized trade-off coordinates (Figs. 6/9): cold-start increase
    /// relative to the minimum cold-start policy, and keep-alive-carbon
    /// increase relative to the minimum-carbon policy. The ideal scheduler
    /// sits at (1.0, 1.0) — the bottom-left corner.
    pub fn tradeoff_coordinates(&self) -> Vec<(String, f64, f64)> {
        let min_cold = self
            .results
            .iter()
            .map(|r| r.metrics.cold_starts)
            .min()
            .unwrap_or(1)
            .max(1) as f64;
        let min_carbon = self
            .results
            .iter()
            .map(|r| r.metrics.keepalive_carbon_g)
            .fold(f64::INFINITY, f64::min)
            .max(1e-12);
        self.results
            .iter()
            .map(|r| {
                (
                    r.name.clone(),
                    r.metrics.cold_starts as f64 / min_cold,
                    r.metrics.keepalive_carbon_g / min_carbon,
                )
            })
            .collect()
    }

    /// Paper-style comparison table (Figs. 5/7 or 8/9 numbers in one view).
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<16} {:>10} {:>12} {:>14} {:>12} {:>12} {:>14}\n",
            "policy", "cold", "latency(s)", "keepalive(g)", "total(g)", "LCP", "IRI"
        ));
        for r in &self.results {
            let m = &r.metrics;
            out.push_str(&format!(
                "{:<16} {:>10} {:>12.4} {:>14.4} {:>12.3} {:>12.2} {:>14.1}\n",
                r.name,
                m.cold_starts,
                m.avg_latency_s(),
                m.keepalive_carbon_g,
                m.total_carbon_g(),
                m.lcp(),
                m.iri(),
            ));
        }
        out
    }

    /// Name of the policy with the lowest LCP (Figs. 7/9 claims).
    pub fn best_lcp(&self) -> Option<&str> {
        self.results
            .iter()
            .min_by(|a, b| a.metrics.lcp().partial_cmp(&b.metrics.lcp()).unwrap())
            .map(|r| r.name.as_str())
    }

    /// Name of the policy with the lowest IRI.
    pub fn best_iri(&self) -> Option<&str> {
        self.results
            .iter()
            .min_by(|a, b| a.metrics.iri().partial_cmp(&b.metrics.iri()).unwrap())
            .map(|r| r.name.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(cold: u64, lat: f64, ka: f64, exec: f64) -> SimMetrics {
        let mut m = SimMetrics::new();
        m.invocations = 100;
        m.cold_starts = cold;
        m.latency.add(lat);
        m.keepalive_carbon_g = ka;
        m.exec_carbon_g = exec;
        m
    }

    fn sample() -> Comparison {
        let mut c = Comparison::new("test");
        c.add("latency-min", metrics(10, 1.0, 900.0, 70.0));
        c.add("carbon-min", metrics(60, 1.8, 12.0, 70.0));
        c.add("lace-rl", metrics(14, 1.05, 49.0, 70.0));
        c
    }

    #[test]
    fn tradeoff_normalizes_to_minimums() {
        let c = sample();
        let coords = c.tradeoff_coordinates();
        let lm = coords.iter().find(|(n, _, _)| n == "latency-min").unwrap();
        assert!((lm.1 - 1.0).abs() < 1e-12); // min cold
        let cm = coords.iter().find(|(n, _, _)| n == "carbon-min").unwrap();
        assert!((cm.2 - 1.0).abs() < 1e-12); // min carbon
        let lr = coords.iter().find(|(n, _, _)| n == "lace-rl").unwrap();
        assert!(lr.1 < 2.0 && lr.2 < 5.0); // near the corner
    }

    #[test]
    fn best_composites() {
        // lace-rl: LCP = 1.05·119 ≈ 125, IRI = 14·49 = 686 — both minima
        // (carbon-min's 60 cold starts × 12 g = 720 loses IRI narrowly).
        let c = sample();
        assert_eq!(c.best_lcp(), Some("lace-rl"));
        assert_eq!(c.best_iri(), Some("lace-rl"));
    }

    #[test]
    fn table_contains_all_policies() {
        let t = sample().table();
        for n in ["latency-min", "carbon-min", "lace-rl"] {
            assert!(t.contains(n));
        }
    }
}
