//! Log-scale value histogram for nonnegative telemetry values.

use crate::util::json::Json;

/// Number of bins: bin 0 holds `[0, 2·LO)`, bin i holds
/// `[LO·2^i, LO·2^(i+1))`, the last bin absorbs everything above.
const BINS: usize = 44;

/// Lower resolution bound: values at or below this land in bin 0.
const LO: f64 = 1e-4;

/// A fixed-footprint log₂-scale histogram of nonnegative values
/// (seconds, grams): 44 bins from 10⁻⁴ doubling per bin (top bin ≈ 8.8×10⁸),
/// plus running count/sum/min/max. Merging is commutative on the bin
/// counts and exact on the counters; `sum` merges by addition, so folding
/// order follows the caller's contract (ascending function-id order for
/// shard invariance, see [`super::SimObs`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Hist {
    /// Number of recorded values.
    pub count: u64,
    /// Sum of recorded values.
    pub sum: f64,
    min: f64,
    max: f64,
    counts: [u64; BINS],
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

impl Hist {
    /// An empty histogram.
    pub fn new() -> Self {
        Hist {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            counts: [0; BINS],
        }
    }

    fn bin(x: f64) -> usize {
        if x <= LO {
            // Also catches NaN and negatives (never expected; bin 0 keeps
            // the invariant that every recorded value lands somewhere).
            return 0;
        }
        // x > LO, so the log is positive and `as usize` floors it.
        (((x / LO).log2()) as usize).min(BINS - 1)
    }

    /// `[lo, hi)` value bounds of bin `i`.
    fn bounds(i: usize) -> (f64, f64) {
        let lo = if i == 0 { 0.0 } else { LO * (1u64 << i) as f64 };
        (lo, LO * (1u64 << (i + 1)) as f64)
    }

    /// Record one value.
    #[inline]
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        self.counts[Self::bin(x)] += 1;
    }

    /// Fold `other` into `self` (bin counts add; min/max widen).
    pub fn merge(&mut self, other: &Hist) {
        self.count += other.count;
        self.sum += other.sum;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest recorded value (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// JSONL `hist` line: summary stats plus the non-empty bins as
    /// `[bin_lo, bin_hi, count]` triples.
    pub fn to_json(&self, name: &str) -> Json {
        let mut bins = Vec::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                let (lo, hi) = Self::bounds(i);
                bins.push(Json::Arr(vec![Json::Num(lo), Json::Num(hi), Json::from(c)]));
            }
        }
        Json::obj(vec![
            ("kind", "hist".into()),
            ("name", name.into()),
            ("count", self.count.into()),
            ("sum", Json::Num(self.sum)),
            ("min", Json::Num(self.min())),
            ("max", Json::Num(self.max())),
            ("mean", Json::Num(self.mean())),
            ("bins", Json::Arr(bins)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_partition_the_positive_axis() {
        // Every bin's lower bound maps back into that bin; a value just
        // below the upper bound stays in it.
        for i in 1..BINS - 1 {
            let (lo, hi) = Hist::bounds(i);
            assert_eq!(Hist::bin(lo), i, "lower bound of bin {i}");
            assert_eq!(Hist::bin(hi * (1.0 - 1e-12)), i, "upper edge of bin {i}");
        }
        assert_eq!(Hist::bin(0.0), 0);
        assert_eq!(Hist::bin(LO), 0);
        assert_eq!(Hist::bin(f64::MAX), BINS - 1);
    }

    #[test]
    fn record_and_stats() {
        let mut h = Hist::new();
        for x in [0.001, 0.002, 0.004, 1.0] {
            h.record(x);
        }
        assert_eq!(h.count, 4);
        assert!((h.sum - 1.007).abs() < 1e-12);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 1.0);
    }

    #[test]
    fn merge_matches_sequential_record() {
        let xs = [0.0003, 0.01, 0.5, 7.0, 120.0];
        let mut whole = Hist::new();
        let mut a = Hist::new();
        let mut b = Hist::new();
        for (i, &x) in xs.iter().enumerate() {
            whole.record(x);
            if i < 2 {
                a.record(x);
            } else {
                b.record(x);
            }
        }
        a.merge(&b);
        assert_eq!(a, whole);
    }

    #[test]
    fn empty_hist_serializes_finite_stats() {
        let h = Hist::new();
        let line = h.to_json("empty").to_string();
        // min/max must not leak ±inf into the JSON output.
        assert!(!line.contains("inf"), "{line}");
        assert!(Json::parse(&line).is_ok(), "{line}");
    }
}
