//! Structured observability: counters, value histograms, span timers, and
//! JSONL export for simulator, trainer, and coordinator runs.
//!
//! The paper's headline numbers (cold-start and idle-carbon reductions vs.
//! the static 60 s baseline) are aggregates; debugging a reproduction needs
//! to see *where* cold starts and idle carbon accrue — per function, per
//! policy, over time. This module provides that visibility without touching
//! the ≥1M inv/s hot path (DESIGN.md §8):
//!
//! * **Disabled by default.** Recording sites are guarded by a relaxed
//!   atomic load ([`enabled`]) or an `Option` check; until a sink is
//!   installed they compile down to a branch over a constant-false flag.
//!   The property test `rust/tests/property_obs.rs` asserts collection is
//!   observation-only: simulation results stay bit-identical either way.
//! * **Shard-count-invariant.** Simulation telemetry is accumulated
//!   per function ([`FuncObs`]) and folded in ascending function-id order
//!   ([`SimObs::totals`]), the same merge contract the sharded simulator
//!   uses for metrics — so a sharded run emits byte-identical telemetry to
//!   a sequential one.
//! * **JSONL streams.** When a sink is installed ([`install_jsonl`]),
//!   each run's events land under `results/obs/<stream>.jsonl` (one JSON
//!   object per line, schema documented in EXPERIMENTS.md §Observability)
//!   and a summary table prints after each experiment.
//!
//! Enable from the CLI with a trailing `--obs` flag, e.g.
//! `lace-rl experiment fig5 --obs`.

#![deny(missing_docs)]

mod hist;
mod sim;
mod sink;

pub use hist::Hist;
pub use sim::{emit_sim, FuncObs, ShardObs, SimObs, BUCKET_S};
pub use sink::ObsSink;

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: OnceLock<ObsSink> = OnceLock::new();

/// Whether a global sink is installed and telemetry collection is on.
/// A relaxed atomic load: cheap enough for per-run (not per-invocation)
/// guards on the simulation path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Install the process-wide JSONL sink writing under `dir` and turn
/// collection on. Idempotent: the first call wins; later calls (even with
/// a different directory) return the already-installed sink. There is no
/// uninstall — the sink lives for the process, matching the one-shot CLI
/// lifecycle.
pub fn install_jsonl(dir: impl Into<PathBuf>) -> &'static ObsSink {
    let dir = dir.into();
    let sink = SINK.get_or_init(|| ObsSink::new(dir));
    ENABLED.store(true, Ordering::Release);
    sink
}

/// The installed sink, if any. `None` until [`install_jsonl`] runs.
pub fn sink() -> Option<&'static ObsSink> {
    if enabled() {
        SINK.get()
    } else {
        None
    }
}

/// A scoped wall-clock timer: records its elapsed time into the sink's
/// span registry on drop. Obtain via [`span`]; hold it for the duration of
/// the phase being measured.
pub struct Span {
    name: &'static str,
    t0: Instant,
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(sink) = sink() {
            sink.record_span_s(self.name, self.t0.elapsed().as_secs_f64());
        }
    }
}

/// Start a scoped span timer named `name` (e.g. `"trainer/rollout"`).
/// Returns `None` — and therefore costs one atomic load — when no sink is
/// installed. Spans are for coarse phases (an episode's rollout, a serving
/// run), never the per-invocation hot loop.
pub fn span(name: &'static str) -> Option<Span> {
    if enabled() {
        Some(Span { name, t0: Instant::now() })
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_by_default_in_tests() {
        // Nothing in the test suite installs the global sink; spans and
        // sink lookups must be no-ops.
        if !enabled() {
            assert!(sink().is_none());
            assert!(span("test/never").is_none());
        }
    }
}
