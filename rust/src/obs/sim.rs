//! Simulation telemetry: per-function accumulators, shard partials, and
//! the id-order fold that keeps merged output shard-count-invariant.

use super::hist::Hist;
use crate::util::json::Json;

/// Width (seconds) of the time buckets behind the cold-start / idle-carbon
/// series (5 min — 288 buckets over the paper's 1-day trace).
pub const BUCKET_S: f64 = 300.0;

/// One time bucket of a per-function series.
#[derive(Debug, Clone, Copy, PartialEq)]
struct BucketCell {
    /// Bucket index (`t / BUCKET_S`).
    bucket: u32,
    cold_starts: u64,
    idle_carbon_g: f64,
}

impl BucketCell {
    fn new(bucket: u32) -> Self {
        BucketCell { bucket, cold_starts: 0, idle_carbon_g: 0.0 }
    }
}

fn bucket_of(t: f64) -> u32 {
    if t.is_finite() && t > 0.0 {
        (t / BUCKET_S) as u32
    } else {
        0
    }
}

/// Telemetry of a single function, accumulated event-by-event during a
/// replay pass in the same order the engine updates its `SimMetrics`
/// partial — which is what makes the id-order fold of [`SimObs::totals`]
/// bitwise-equal to the run's metrics (see `rust/tests/property_obs.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct FuncObs {
    /// Invocations served cold.
    pub cold_starts: u64,
    /// Invocations served from a warm pod.
    pub warm_starts: u64,
    /// Pods whose keep-alive window lapsed unused.
    pub expiries: u64,
    /// Total cold-start latency (s).
    pub cold_latency_s: f64,
    /// Idle (keep-alive) carbon (g) over all idle spans: reuse, expiry,
    /// and end-of-trace flush. Totals match `SimMetrics::keepalive_carbon_g`.
    pub idle_carbon_g: f64,
    /// The wasted subset of [`FuncObs::idle_carbon_g`]: carbon of windows
    /// that expired without a reuse.
    pub expiry_carbon_g: f64,
    /// Keep-alive durations chosen by the policy (s).
    pub keep_hist: Hist,
    /// Cold-start latencies (s).
    pub cold_hist: Hist,
    /// Idle carbon per expiry (g).
    pub expiry_hist: Hist,
    /// Pod-spawn retries under fault injection (`chaos`).
    pub spawn_retries: u64,
    /// Total spawn-retry backoff delay (s) under fault injection.
    pub retry_delay_s: f64,
    /// Decisions degraded to the static fallback action (chaos timeout).
    pub degraded_decisions: u64,
    /// Decisions taken on stale-carbon fallback estimates (chaos outage).
    pub stale_ci_decisions: u64,
    /// Per-cold-start retry backoff delays (s) under fault injection.
    pub retry_hist: Hist,
    /// Time-bucketed series, sorted by bucket index.
    buckets: Vec<BucketCell>,
}

impl FuncObs {
    pub(crate) fn new() -> Self {
        FuncObs {
            cold_starts: 0,
            warm_starts: 0,
            expiries: 0,
            cold_latency_s: 0.0,
            idle_carbon_g: 0.0,
            expiry_carbon_g: 0.0,
            keep_hist: Hist::new(),
            cold_hist: Hist::new(),
            expiry_hist: Hist::new(),
            spawn_retries: 0,
            retry_delay_s: 0.0,
            degraded_decisions: 0,
            stale_ci_decisions: 0,
            retry_hist: Hist::new(),
            buckets: Vec::new(),
        }
    }

    /// The cell for time `t`, inserted in sorted position if absent.
    /// Events arrive nearly in time order (expiry timestamps can trail the
    /// arrival clock), so the scan from the tail is almost always one
    /// comparison.
    fn cell(&mut self, t: f64) -> &mut BucketCell {
        let b = bucket_of(t);
        match self.buckets.iter().rposition(|c| c.bucket <= b) {
            Some(i) if self.buckets[i].bucket == b => &mut self.buckets[i],
            Some(i) => {
                self.buckets.insert(i + 1, BucketCell::new(b));
                &mut self.buckets[i + 1]
            }
            None => {
                self.buckets.insert(0, BucketCell::new(b));
                &mut self.buckets[0]
            }
        }
    }

    pub(crate) fn on_expiry(&mut self, t: f64, carbon_g: f64) {
        self.expiries += 1;
        self.idle_carbon_g += carbon_g;
        self.expiry_carbon_g += carbon_g;
        self.expiry_hist.record(carbon_g);
        self.cell(t).idle_carbon_g += carbon_g;
    }

    pub(crate) fn on_warm(&mut self, t: f64, idle_carbon_g: f64) {
        self.warm_starts += 1;
        self.idle_carbon_g += idle_carbon_g;
        self.cell(t).idle_carbon_g += idle_carbon_g;
    }

    pub(crate) fn on_cold(&mut self, t: f64, cold_lat_s: f64) {
        self.cold_starts += 1;
        self.cold_latency_s += cold_lat_s;
        self.cold_hist.record(cold_lat_s);
        self.cell(t).cold_starts += 1;
    }

    pub(crate) fn on_decision(&mut self, keep_s: f64) {
        self.keep_hist.record(keep_s);
    }

    pub(crate) fn on_flush(&mut self, horizon: f64, idle_carbon_g: f64) {
        self.idle_carbon_g += idle_carbon_g;
        self.cell(horizon).idle_carbon_g += idle_carbon_g;
    }

    pub(crate) fn on_spawn_retry(&mut self, retries: u64, delay_s: f64) {
        self.spawn_retries += retries;
        self.retry_delay_s += delay_s;
        self.retry_hist.record(delay_s);
    }

    pub(crate) fn on_degraded(&mut self) {
        self.degraded_decisions += 1;
    }

    pub(crate) fn on_stale(&mut self) {
        self.stale_ci_decisions += 1;
    }

    /// Fold `other` into `self`. Scalars and histograms add; the bucket
    /// series merge by bucket index (both inputs are sorted).
    fn merge(&mut self, other: &FuncObs) {
        self.cold_starts += other.cold_starts;
        self.warm_starts += other.warm_starts;
        self.expiries += other.expiries;
        self.cold_latency_s += other.cold_latency_s;
        self.idle_carbon_g += other.idle_carbon_g;
        self.expiry_carbon_g += other.expiry_carbon_g;
        self.keep_hist.merge(&other.keep_hist);
        self.cold_hist.merge(&other.cold_hist);
        self.expiry_hist.merge(&other.expiry_hist);
        self.spawn_retries += other.spawn_retries;
        self.retry_delay_s += other.retry_delay_s;
        self.degraded_decisions += other.degraded_decisions;
        self.stale_ci_decisions += other.stale_ci_decisions;
        self.retry_hist.merge(&other.retry_hist);
        let mut merged = Vec::with_capacity(self.buckets.len() + other.buckets.len());
        let (mut i, mut j) = (0, 0);
        while i < self.buckets.len() && j < other.buckets.len() {
            let (a, b) = (self.buckets[i], other.buckets[j]);
            if a.bucket < b.bucket {
                merged.push(a);
                i += 1;
            } else if b.bucket < a.bucket {
                merged.push(b);
                j += 1;
            } else {
                merged.push(BucketCell {
                    bucket: a.bucket,
                    cold_starts: a.cold_starts + b.cold_starts,
                    idle_carbon_g: a.idle_carbon_g + b.idle_carbon_g,
                });
                i += 1;
                j += 1;
            }
        }
        merged.extend_from_slice(&self.buckets[i..]);
        merged.extend_from_slice(&other.buckets[j..]);
        self.buckets = merged;
    }

    /// The time series as `(bucket start s, cold starts, idle carbon g)`
    /// rows in ascending time order (empty buckets omitted).
    pub fn bucket_series(&self) -> Vec<(f64, u64, f64)> {
        self.buckets
            .iter()
            .map(|c| (c.bucket as f64 * BUCKET_S, c.cold_starts, c.idle_carbon_g))
            .collect()
    }
}

/// Telemetry of one contiguous function-id shard during a replay pass.
/// Created by the engine when collection is on; collected into a
/// [`SimObs`] after the pass.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardObs {
    f_lo: usize,
    funcs: Vec<FuncObs>,
}

impl ShardObs {
    pub(crate) fn new(f_lo: usize, n: usize) -> Self {
        ShardObs { f_lo, funcs: (0..n).map(|_| FuncObs::new()).collect() }
    }

    /// The accumulator for global function id `f`.
    #[inline]
    pub(crate) fn func(&mut self, f: usize) -> &mut FuncObs {
        &mut self.funcs[f - self.f_lo]
    }
}

/// One run's merged telemetry: per-function rows plus all-function totals.
///
/// Shards absorb in ascending shard (= function-id) order and the totals
/// fold per-function partials in that same order — the metrics merge
/// contract (`simulator::sharded`) — so a sharded run's `SimObs` is equal,
/// f64 bits included, to a sequential run's.
#[derive(Debug, Clone, PartialEq)]
pub struct SimObs {
    /// Width (s) of the series buckets ([`BUCKET_S`]).
    pub bucket_s: f64,
    /// `(function id, telemetry)` rows in ascending id order.
    pub funcs: Vec<(u32, FuncObs)>,
    /// All-function totals, folded in ascending function-id order.
    pub totals: FuncObs,
}

impl SimObs {
    pub(crate) fn new() -> Self {
        SimObs { bucket_s: BUCKET_S, funcs: Vec::new(), totals: FuncObs::new() }
    }

    /// Fold one shard's partials in. Must be called in ascending shard
    /// order; each function id appears in exactly one shard.
    pub(crate) fn absorb(&mut self, shard: ShardObs) {
        let ShardObs { f_lo, funcs } = shard;
        self.funcs.reserve(funcs.len());
        for (i, fo) in funcs.into_iter().enumerate() {
            self.totals.merge(&fo);
            self.funcs.push(((f_lo + i) as u32, fo));
        }
    }

    /// The JSONL lines for this run (schema in EXPERIMENTS.md
    /// §Observability): a `meta` header, a `totals` line, one `func` line
    /// per function (with its inline `[t, cold_starts, idle_carbon_g]`
    /// series), the run-level `bucket` series, and the three totals
    /// histograms.
    pub fn jsonl_lines(&self, label: &str) -> Vec<Json> {
        let t = &self.totals;
        let mut lines = Vec::with_capacity(self.funcs.len() + t.buckets.len() + 6);
        lines.push(Json::obj(vec![
            ("kind", "meta".into()),
            ("schema", 2u64.into()),
            ("stream", label.into()),
            ("bucket_s", Json::Num(self.bucket_s)),
            ("functions", (self.funcs.len() as u64).into()),
        ]));
        lines.push(Json::obj(vec![
            ("kind", "totals".into()),
            ("cold_starts", t.cold_starts.into()),
            ("warm_starts", t.warm_starts.into()),
            ("expiries", t.expiries.into()),
            ("cold_latency_s", Json::Num(t.cold_latency_s)),
            ("idle_carbon_g", Json::Num(t.idle_carbon_g)),
            ("expiry_carbon_g", Json::Num(t.expiry_carbon_g)),
            ("spawn_retries", t.spawn_retries.into()),
            ("retry_delay_s", Json::Num(t.retry_delay_s)),
            ("degraded_decisions", t.degraded_decisions.into()),
            ("stale_ci_decisions", t.stale_ci_decisions.into()),
        ]));
        for (id, fo) in &self.funcs {
            let series = fo
                .bucket_series()
                .into_iter()
                .map(|(t0, cold, carbon)| {
                    Json::Arr(vec![Json::Num(t0), Json::from(cold), Json::Num(carbon)])
                })
                .collect();
            lines.push(Json::obj(vec![
                ("kind", "func".into()),
                ("id", (*id as u64).into()),
                ("cold_starts", fo.cold_starts.into()),
                ("warm_starts", fo.warm_starts.into()),
                ("expiries", fo.expiries.into()),
                ("cold_latency_s", Json::Num(fo.cold_latency_s)),
                ("idle_carbon_g", Json::Num(fo.idle_carbon_g)),
                ("expiry_carbon_g", Json::Num(fo.expiry_carbon_g)),
                ("series", Json::Arr(series)),
            ]));
        }
        for (t0, cold, carbon) in t.bucket_series() {
            lines.push(Json::obj(vec![
                ("kind", "bucket".into()),
                ("t", Json::Num(t0)),
                ("cold_starts", cold.into()),
                ("idle_carbon_g", Json::Num(carbon)),
            ]));
        }
        lines.push(t.keep_hist.to_json("keepalive_s"));
        lines.push(t.cold_hist.to_json("cold_start_s"));
        lines.push(t.expiry_hist.to_json("idle_carbon_per_expiry_g"));
        lines.push(t.retry_hist.to_json("retry_delay_s"));
        lines
    }
}

/// Emit one simulation's telemetry as `<stream>.jsonl` through the
/// installed sink; a silent no-op when no sink is installed, a warning
/// (never an error) when the write fails — telemetry must not take an
/// experiment down.
pub fn emit_sim(stream: &str, obs: &SimObs) {
    if let Some(sink) = super::sink() {
        if let Err(e) = sink.emit_jsonl(stream, &obs.jsonl_lines(stream)) {
            eprintln!("[obs] failed to write stream '{stream}': {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_of_order_bucket_inserts_stay_sorted() {
        let mut fo = FuncObs::new();
        fo.on_cold(10.0, 1.0); // bucket 0
        fo.on_cold(950.0, 1.0); // bucket 3
        fo.on_expiry(400.0, 0.5); // bucket 1, behind the clock
        fo.on_warm(950.0, 0.25); // bucket 3 again
        let s = fo.bucket_series();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], (0.0, 1, 0.0));
        assert_eq!(s[1], (300.0, 0, 0.5));
        assert_eq!(s[2], (900.0, 1, 0.25));
    }

    #[test]
    fn absorb_in_id_order_matches_single_shard() {
        // The same events recorded through one shard of 4 functions vs two
        // shards of 2 must produce identical SimObs.
        let mut single = ShardObs::new(0, 4);
        single.func(0).on_cold(5.0, 2.0);
        single.func(2).on_warm(100.0, 0.125);
        single.func(3).on_decision(60.0);
        let mut whole = SimObs::new();
        whole.absorb(single);

        let mut lo = ShardObs::new(0, 2);
        lo.func(0).on_cold(5.0, 2.0);
        let mut hi = ShardObs::new(2, 2);
        hi.func(2).on_warm(100.0, 0.125);
        hi.func(3).on_decision(60.0);
        let mut split = SimObs::new();
        split.absorb(lo);
        split.absorb(hi);

        assert_eq!(whole, split);
        assert_eq!(whole.totals.cold_starts, 1);
        assert_eq!(whole.totals.warm_starts, 1);
        assert_eq!(whole.totals.keep_hist.count, 1);
    }

    #[test]
    fn jsonl_lines_parse_and_cover_all_kinds() {
        let mut shard = ShardObs::new(0, 2);
        shard.func(0).on_cold(5.0, 1.5);
        shard.func(0).on_decision(10.0);
        shard.func(1).on_warm(400.0, 0.01);
        let mut obs = SimObs::new();
        obs.absorb(shard);
        let lines = obs.jsonl_lines("test");
        let mut kinds = Vec::new();
        for l in &lines {
            let parsed = Json::parse(&l.to_string()).unwrap();
            kinds.push(parsed.get("kind").unwrap().as_str().unwrap().to_string());
        }
        for want in ["meta", "totals", "func", "bucket", "hist"] {
            assert!(kinds.iter().any(|k| k == want), "missing kind {want}");
        }
    }
}
