//! The JSONL telemetry sink: counters, span registry, stream files.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, MutexGuard};

use crate::util::json::Json;
use crate::util::stats::Running;

/// Recover from lock poisoning: telemetry must never take the process
/// down, and a panicking recorder leaves the registries merely incomplete.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Process-wide telemetry sink (install via [`super::install_jsonl`]).
///
/// Holds three registries, each behind its own mutex — recording happens
/// on run boundaries (a stream per simulation, a span per phase), never
/// inside the per-invocation loop, so contention is irrelevant:
///
/// * monotonic **counters**, keyed by name (`serve/requests`, …);
/// * **span** wall-clock stats, keyed by span name ([`super::span`]);
/// * the list of **stream** files written so far ([`ObsSink::emit_jsonl`]).
///
/// Counters and spans are cumulative for the process lifetime — an
/// `experiment all` run prints a growing summary after each experiment.
pub struct ObsSink {
    dir: PathBuf,
    counters: Mutex<BTreeMap<String, u64>>,
    spans: Mutex<BTreeMap<&'static str, Running>>,
    streams: Mutex<Vec<PathBuf>>,
}

impl ObsSink {
    pub(crate) fn new(dir: PathBuf) -> Self {
        ObsSink {
            dir,
            counters: Mutex::new(BTreeMap::new()),
            spans: Mutex::new(BTreeMap::new()),
            streams: Mutex::new(Vec::new()),
        }
    }

    /// Directory the JSONL streams are written under (e.g. `results/obs`).
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Add `delta` to the named monotonic counter (created at 0).
    pub fn add_counter(&self, name: &str, delta: u64) {
        *lock(&self.counters).entry(name.to_string()).or_insert(0) += delta;
    }

    /// Current value of a counter (0 if never touched). Mostly for tests.
    pub fn counter(&self, name: &str) -> u64 {
        lock(&self.counters).get(name).copied().unwrap_or(0)
    }

    /// Record one span duration; called by [`super::Span`] on drop.
    pub fn record_span_s(&self, name: &'static str, seconds: f64) {
        lock(&self.spans).entry(name).or_insert_with(Running::new).add(seconds);
    }

    /// Write `lines` as `<dir>/<stream>.jsonl` (one JSON object per line,
    /// directory created on demand, non-filename characters in `stream`
    /// replaced by `_`). A rerun of the same stream overwrites the file —
    /// each stream is one run's snapshot, not an append log. Returns the
    /// path written.
    pub fn emit_jsonl(&self, stream: &str, lines: &[Json]) -> std::io::Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)?;
        let path = self.dir.join(format!("{}.jsonl", sanitize(stream)));
        let mut out = String::new();
        for line in lines {
            out.push_str(&line.to_string());
            out.push('\n');
        }
        std::fs::write(&path, out)?;
        let mut streams = lock(&self.streams);
        if !streams.contains(&path) {
            streams.push(path.clone());
        }
        Ok(path)
    }

    /// Human-readable summary table: counters, span stats, and the stream
    /// files written so far. Empty string when nothing was recorded (so
    /// callers can `print!` unconditionally).
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let counters = lock(&self.counters);
        let spans = lock(&self.spans);
        let streams = lock(&self.streams);
        if counters.is_empty() && spans.is_empty() && streams.is_empty() {
            return String::new();
        }
        let mut out = String::new();
        let _ = writeln!(out, "-- obs summary ({}) --", self.dir.display());
        for (name, v) in counters.iter() {
            let _ = writeln!(out, "  counter {name:<32} {v}");
        }
        for (name, r) in spans.iter() {
            let _ = writeln!(
                out,
                "  span    {name:<32} n={} total={:.3}s mean={:.3}s max={:.3}s",
                r.count,
                r.sum,
                r.mean(),
                r.max
            );
        }
        for path in streams.iter() {
            let _ = writeln!(out, "  stream  {}", path.display());
        }
        out
    }
}

/// Keep stream names filesystem-safe without pulling in a path library.
fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') {
                c
            } else {
                '_'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let sink = ObsSink::new(PathBuf::from("results/obs-test"));
        sink.add_counter("a/b", 2);
        sink.add_counter("a/b", 3);
        assert_eq!(sink.counter("a/b"), 5);
        assert_eq!(sink.counter("missing"), 0);
        assert!(sink.summary().contains("a/b"));
    }

    #[test]
    fn spans_aggregate() {
        let sink = ObsSink::new(PathBuf::from("results/obs-test"));
        sink.record_span_s("phase/x", 0.5);
        sink.record_span_s("phase/x", 1.5);
        let s = sink.summary();
        assert!(s.contains("phase/x"), "{s}");
        assert!(s.contains("n=2"), "{s}");
    }

    #[test]
    fn sanitize_keeps_safe_chars() {
        assert_eq!(sanitize("general_lace-rl.v1"), "general_lace-rl.v1");
        assert_eq!(sanitize("a/b c"), "a_b_c");
    }

    #[test]
    fn emit_writes_one_object_per_line() {
        let dir = std::env::temp_dir().join(format!("lace-obs-{}", std::process::id()));
        let sink = ObsSink::new(dir.clone());
        let lines = vec![
            Json::obj(vec![("kind", "meta".into()), ("schema", 1u64.into())]),
            Json::obj(vec![("kind", "x".into()), ("v", Json::Num(1.5))]),
        ];
        let path = sink.emit_jsonl("stream a", &lines).unwrap();
        assert_eq!(path.file_name().unwrap().to_str().unwrap(), "stream_a.jsonl");
        let body = std::fs::read_to_string(&path).unwrap();
        let parsed: Vec<Json> =
            body.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].get("schema").and_then(Json::as_f64), Some(1.0));
        // Emitting the same stream twice registers it once.
        sink.emit_jsonl("stream a", &lines).unwrap();
        assert_eq!(sink.summary().matches("stream_a.jsonl").count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
