//! Carbon-Minimizing baseline (§IV-A5): strictly minimizes idle carbon by
//! always choosing the shortest keep-alive, accepting the resulting cold
//! starts (the paper's high-latency extreme in Figs. 5b/8b).

use crate::policy::{BoxedPolicy, DecisionContext, KeepAlivePolicy};

#[derive(Debug, Clone, Default)]
pub struct CarbonMin;

impl KeepAlivePolicy for CarbonMin {
    fn name(&self) -> &str {
        "carbon-min"
    }

    fn decide(&mut self, _ctx: &DecisionContext) -> usize {
        0 // shortest keep-alive in the action set
    }

    fn fork(&self) -> Option<BoxedPolicy> {
        Some(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_util::{ctx, profile};
    use crate::KEEP_ALIVE_ACTIONS;

    #[test]
    fn always_shortest() {
        let f = profile(10.0);
        let c = ctx(&f, 5.0, [1.0; 5], 0.0); // even when reuse is certain
        assert_eq!(KEEP_ALIVE_ACTIONS[CarbonMin.decide(&c)], 1.0);
    }
}
