//! DPSO baseline — the EcoLife-style Particle Swarm Optimization
//! metaheuristic (§IV-A5, [22]).
//!
//! EcoLife co-selects keep-alive durations with PSO per decision. We
//! reproduce the decision procedure on our action space: a swarm explores
//! the continuous keep-alive range [1, 60] s, fitness is the *expected*
//! blended cost under the function's reuse-probability profile
//! (piecewise-linear interpolation of p_k between the discrete grid
//! points), and the converged global best is snapped to the nearest
//! discrete action.
//!
//! The point of this baseline is twofold (paper §IV-E): decision *quality*
//! — population heuristics rank close to LACE-RL on carbon but worse on
//! cold starts — and decision *cost* — iterative population updates per
//! decision are orders of magnitude slower than one DQN forward pass
//! (4,600× in the paper). `benches/decision_latency.rs` measures ours.

use crate::energy::JOULES_PER_KWH;
use crate::policy::{blended_cost, BoxedPolicy, DecisionContext, KeepAlivePolicy};
use crate::util::rng::Rng;
use crate::KEEP_ALIVE_ACTIONS;
use std::collections::HashMap;

/// PSO hyper-parameters (standard constriction-style settings).
#[derive(Debug, Clone)]
pub struct DpsoConfig {
    pub particles: usize,
    pub iterations: usize,
    pub inertia: f64,
    pub c_personal: f64,
    pub c_global: f64,
    pub seed: u64,
}

impl Default for DpsoConfig {
    fn default() -> Self {
        DpsoConfig {
            particles: 50,
            iterations: 40,
            inertia: 0.72,
            c_personal: 1.49,
            c_global: 1.49,
            seed: 11,
        }
    }
}

pub struct Dpso {
    cfg: DpsoConfig,
    /// One RNG stream per function id, derived statelessly from the seed
    /// (`Rng::stream`): each function's swarm randomness depends only on
    /// its own decision history, so decisions are invariant under sharding
    /// the trace across threads (`simulator::sharded`).
    streams: HashMap<u32, Rng>,
    // Reused particle buffers (avoid per-decision allocation).
    pos: Vec<f64>,
    vel: Vec<f64>,
    pbest: Vec<f64>,
    pbest_cost: Vec<f64>,
}

impl Dpso {
    pub fn new(cfg: DpsoConfig) -> Self {
        let n = cfg.particles;
        Dpso {
            cfg,
            streams: HashMap::new(),
            pos: vec![0.0; n],
            vel: vec![0.0; n],
            pbest: vec![0.0; n],
            pbest_cost: vec![f64::INFINITY; n],
        }
    }

    /// Reuse probability at a continuous keep-alive `k`: piecewise-linear
    /// interpolation of the discrete p_k grid, clamped at the ends.
    fn reuse_prob_at(probs: &[f64; 5], k: f64) -> f64 {
        let grid = &KEEP_ALIVE_ACTIONS;
        if k <= grid[0] {
            return probs[0];
        }
        for i in 1..grid.len() {
            if k <= grid[i] {
                let f = (k - grid[i - 1]) / (grid[i] - grid[i - 1]);
                return probs[i - 1] + f * (probs[i] - probs[i - 1]);
            }
        }
        probs[grid.len() - 1]
    }

    /// Expected blended cost of keep-alive `k` (the PSO fitness).
    fn fitness(ctx: &DecisionContext, k: f64) -> f64 {
        let p = Self::reuse_prob_at(&ctx.reuse_probs, k);
        let cold = (1.0 - p) * ctx.func.cold_start_s;
        // Expected idle span: reuse arrives uniformly within k (approx.
        // k/2) with prob p, otherwise the full timeout burns.
        let expected_idle = p * (k * 0.5) + (1.0 - p) * k;
        let carbon = ctx.idle_power_w * expected_idle * ctx.ci / JOULES_PER_KWH;
        blended_cost(ctx.lambda_carbon, cold, carbon)
    }
}

impl KeepAlivePolicy for Dpso {
    fn name(&self) -> &str {
        "dpso-ecolife"
    }

    fn decide(&mut self, ctx: &DecisionContext) -> usize {
        let lo = KEEP_ALIVE_ACTIONS[0];
        let hi = KEEP_ALIVE_ACTIONS[KEEP_ALIVE_ACTIONS.len() - 1];
        let n = self.cfg.particles;
        let seed = self.cfg.seed;
        let rng = self
            .streams
            .entry(ctx.func.id)
            .or_insert_with(|| Rng::stream(seed, ctx.func.id as u64));

        let mut gbest = lo;
        let mut gbest_cost = f64::INFINITY;

        // Init swarm.
        for i in 0..n {
            self.pos[i] = rng.range(lo, hi);
            self.vel[i] = rng.range(-(hi - lo) * 0.1, (hi - lo) * 0.1);
            let c = Self::fitness(ctx, self.pos[i]);
            self.pbest[i] = self.pos[i];
            self.pbest_cost[i] = c;
            if c < gbest_cost {
                gbest_cost = c;
                gbest = self.pos[i];
            }
        }

        // Iterate.
        for _ in 0..self.cfg.iterations {
            for i in 0..n {
                let r1 = rng.f64();
                let r2 = rng.f64();
                self.vel[i] = self.cfg.inertia * self.vel[i]
                    + self.cfg.c_personal * r1 * (self.pbest[i] - self.pos[i])
                    + self.cfg.c_global * r2 * (gbest - self.pos[i]);
                self.pos[i] = (self.pos[i] + self.vel[i]).clamp(lo, hi);
                let c = Self::fitness(ctx, self.pos[i]);
                if c < self.pbest_cost[i] {
                    self.pbest_cost[i] = c;
                    self.pbest[i] = self.pos[i];
                    if c < gbest_cost {
                        gbest_cost = c;
                        gbest = self.pos[i];
                    }
                }
            }
        }

        // Snap to the nearest discrete action, breaking ties by cost.
        let mut best_a = 0;
        let mut best_d = f64::INFINITY;
        for (a, &k) in KEEP_ALIVE_ACTIONS.iter().enumerate() {
            let d = (k - gbest).abs();
            if d < best_d {
                best_d = d;
                best_a = a;
            }
        }
        best_a
    }

    fn fork(&self) -> Option<BoxedPolicy> {
        // A fresh instance behaves identically: streams are derived
        // statelessly per function id, and the swarm buffers are fully
        // re-initialized at every decision.
        Some(Box::new(Dpso::new(self.cfg.clone())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_util::{ctx, profile};

    fn decide(cold_s: f64, probs: [f64; 5], lambda: f64, ci: f64) -> usize {
        let f = profile(cold_s);
        let c = ctx(&f, ci, probs, lambda);
        Dpso::new(DpsoConfig::default()).decide(&c)
    }

    #[test]
    fn interpolation_matches_grid_points() {
        let probs = [0.1, 0.3, 0.5, 0.8, 0.9];
        for (i, &k) in KEEP_ALIVE_ACTIONS.iter().enumerate() {
            assert!((Dpso::reuse_prob_at(&probs, k) - probs[i]).abs() < 1e-12);
        }
        // Midpoint between 10 and 30:
        assert!((Dpso::reuse_prob_at(&probs, 20.0) - 0.65).abs() < 1e-12);
    }

    #[test]
    fn latency_leaning_picks_long_keepalive() {
        // Expensive cold start, λ→0 and most reuse arriving late.
        let a = decide(10.0, [0.05, 0.1, 0.2, 0.6, 0.95], 0.05, 300.0);
        assert!(KEEP_ALIVE_ACTIONS[a] >= 30.0, "got {}", KEEP_ALIVE_ACTIONS[a]);
    }

    #[test]
    fn carbon_leaning_picks_short_keepalive() {
        // Cheap cold start, λ→1, high CI.
        let a = decide(0.05, [0.05, 0.1, 0.2, 0.6, 0.95], 0.98, 900.0);
        assert!(KEEP_ALIVE_ACTIONS[a] <= 5.0, "got {}", KEEP_ALIVE_ACTIONS[a]);
    }

    #[test]
    fn deterministic_per_construction() {
        let f = profile(2.0);
        let c = ctx(&f, 300.0, [0.1, 0.4, 0.6, 0.8, 0.9], 0.5);
        let a1 = Dpso::new(DpsoConfig::default()).decide(&c);
        let a2 = Dpso::new(DpsoConfig::default()).decide(&c);
        assert_eq!(a1, a2);
    }

    #[test]
    fn decisions_invariant_under_function_interleaving() {
        // Per-function streams: function 1's decisions are the same whether
        // function 0's decisions happen in between or not (the sharding
        // invariance the fork contract requires).
        let f0 = profile(2.0);
        let mut f1 = profile(2.0);
        f1.id = 1;
        let c0 = ctx(&f0, 300.0, [0.1, 0.4, 0.6, 0.8, 0.9], 0.5);
        let c1 = ctx(&f1, 500.0, [0.2, 0.3, 0.5, 0.7, 0.95], 0.5);

        let mut interleaved = Dpso::new(DpsoConfig::default());
        let mut alone = Dpso::new(DpsoConfig::default());
        let mut got = Vec::new();
        for _ in 0..3 {
            interleaved.decide(&c0);
            got.push(interleaved.decide(&c1));
        }
        let want: Vec<usize> = (0..3).map(|_| alone.decide(&c1)).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn fork_matches_original() {
        let f = profile(2.0);
        let c = ctx(&f, 300.0, [0.1, 0.4, 0.6, 0.8, 0.9], 0.5);
        let mut orig = Dpso::new(DpsoConfig::default());
        let mut forked = orig.fork().unwrap();
        for _ in 0..3 {
            assert_eq!(orig.decide(&c), forked.decide(&c));
        }
    }

    #[test]
    fn pso_close_to_exhaustive_grid() {
        // PSO should not be much worse than brute-force over a fine grid.
        let f = profile(3.0);
        let c = ctx(&f, 500.0, [0.2, 0.35, 0.5, 0.75, 0.92], 0.5);
        let a = Dpso::new(DpsoConfig::default()).decide(&c);
        let pso_cost = Dpso::fitness(&c, KEEP_ALIVE_ACTIONS[a]);
        let best_grid = KEEP_ALIVE_ACTIONS
            .iter()
            .map(|&k| Dpso::fitness(&c, k))
            .fold(f64::INFINITY, f64::min);
        assert!(pso_cost <= best_grid * 1.05 + 1e-9);
    }
}
