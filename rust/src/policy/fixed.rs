//! Fixed-timeout policy — Huawei's production configuration (§IV-A5).

use crate::policy::{BoxedPolicy, DecisionContext, KeepAlivePolicy};
use crate::KEEP_ALIVE_ACTIONS;

/// Always keeps pods alive for the same duration. `FixedTimeout::huawei()`
/// is the 60 s state-of-the-practice baseline: *static* in the strong
/// sense — the window is armed when the pod first idles and is **not**
/// refreshed by subsequent reuse (no per-invocation adaptation at all; see
/// `KeepAlivePolicy::refreshes_timer`). `FixedTimeout::new(k)` is the
/// adaptive-refresh sweep variant used by Fig. 2.
#[derive(Debug, Clone)]
pub struct FixedTimeout {
    action: usize,
    name: String,
    refresh: bool,
}

impl FixedTimeout {
    /// Refreshing fixed timeout at the action closest to `timeout_s`
    /// (the Fig. 2 sweep semantics: every completion re-arms the timer).
    pub fn new(timeout_s: f64) -> Self {
        let action = KEEP_ALIVE_ACTIONS
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                (*a - timeout_s)
                    .abs()
                    .partial_cmp(&(*b - timeout_s).abs())
                    .unwrap()
            })
            .map(|(i, _)| i)
            .unwrap();
        FixedTimeout {
            action,
            name: format!("fixed-{}s", KEEP_ALIVE_ACTIONS[action]),
            refresh: true,
        }
    }

    /// Huawei's static 60 s keep-alive: non-refreshing window.
    pub fn huawei() -> Self {
        FixedTimeout {
            action: KEEP_ALIVE_ACTIONS.len() - 1,
            name: "huawei-60s".to_string(),
            refresh: false,
        }
    }

    pub fn action(&self) -> usize {
        self.action
    }
}

impl KeepAlivePolicy for FixedTimeout {
    fn name(&self) -> &str {
        &self.name
    }

    fn decide(&mut self, _ctx: &DecisionContext) -> usize {
        self.action
    }

    fn refreshes_timer(&self) -> bool {
        self.refresh
    }

    fn fork(&self) -> Option<BoxedPolicy> {
        Some(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_util::{ctx, profile};

    #[test]
    fn huawei_is_60s() {
        let mut p = FixedTimeout::huawei();
        assert_eq!(KEEP_ALIVE_ACTIONS[p.action()], 60.0);
        let f = profile(1.0);
        let c = ctx(&f, 300.0, [0.5; 5], 0.5);
        assert_eq!(p.decide(&c), 4);
    }

    #[test]
    fn snaps_to_nearest_action() {
        assert_eq!(KEEP_ALIVE_ACTIONS[FixedTimeout::new(7.0).action()], 5.0);
        assert_eq!(KEEP_ALIVE_ACTIONS[FixedTimeout::new(8.0).action()], 10.0);
        assert_eq!(KEEP_ALIVE_ACTIONS[FixedTimeout::new(0.0).action()], 1.0);
        assert_eq!(KEEP_ALIVE_ACTIONS[FixedTimeout::new(1e9).action()], 60.0);
    }
}
