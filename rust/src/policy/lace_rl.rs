//! LACE-RL inference policy (§III): encode the decision context (Eq. 6),
//! one Q-network forward pass, act greedily.
//!
//! The Q-function is pluggable behind [`QFunction`]:
//! * [`crate::policy::native_mlp::NativeMlp`] — pure-Rust forward, the
//!   ~µs fast path (perf-pass winner, see EXPERIMENTS.md §Perf);
//! * [`crate::runtime::QNetInfer`]-backed [`PjrtQ`] — the canonical AOT
//!   executable (Pallas fused-MLP kernel under PJRT).
//!
//! Both paths are asserted to agree in the integration tests.

use crate::policy::{BoxedPolicy, DecisionContext, KeepAlivePolicy};
use crate::rl::encoder::{encode, STATE_DIM};

/// Minimal Q-function interface: state in, per-action Q-values out.
pub trait QFunction {
    fn q_values(&mut self, state: &[f32; STATE_DIM]) -> [f32; 5];

    /// Build a shard-local `LaceRlPolicy` over this Q-function for the
    /// sharded simulator (`KeepAlivePolicy::fork`). Default `None`:
    /// backends that can't cross threads cheaply (PJRT executables hold
    /// client handles) keep the sequential path.
    fn fork_policy(&self) -> Option<BoxedPolicy> {
        None
    }
}

impl QFunction for crate::policy::native_mlp::NativeMlp {
    fn q_values(&mut self, state: &[f32; STATE_DIM]) -> [f32; 5] {
        let q = self.forward(state);
        let mut out = [0.0f32; 5];
        out.copy_from_slice(&q[..5]);
        out
    }

    fn fork_policy(&self) -> Option<BoxedPolicy> {
        // Frozen weights shared behind the Arc; per-fork scratch only.
        use crate::policy::native_mlp::NativeMlp;
        Some(Box::new(LaceRlPolicy::new(NativeMlp::from_arc(self.params_arc()))))
    }
}

/// PJRT-backed Q-function using the batch-1 inference executable.
pub struct PjrtQ {
    infer: crate::runtime::QNetInfer,
    params: crate::rl::qnet::QNetParams,
}

impl PjrtQ {
    pub fn new(infer: crate::runtime::QNetInfer, params: crate::rl::qnet::QNetParams) -> Self {
        assert_eq!(infer.batch, 1, "PjrtQ needs the batch-1 executable");
        PjrtQ { infer, params }
    }
}

impl QFunction for PjrtQ {
    fn q_values(&mut self, state: &[f32; STATE_DIM]) -> [f32; 5] {
        let q = self
            .infer
            .q_values(&self.params, state)
            .expect("PJRT inference failed");
        let mut out = [0.0f32; 5];
        out.copy_from_slice(&q[..5]);
        out
    }
}

/// One recorded decision (for the Fig. 10b interpretability analysis).
#[derive(Debug, Clone, Copy)]
pub struct DecisionRecord {
    pub t: f64,
    pub action: usize,
    pub ci: f64,
}

/// The LACE-RL policy: greedy over the learned Q-function.
pub struct LaceRlPolicy<Q: QFunction> {
    q: Q,
    name: String,
    /// When set, every decision is recorded (Fig. 10b).
    pub record: bool,
    pub decisions: Vec<DecisionRecord>,
}

impl<Q: QFunction> LaceRlPolicy<Q> {
    pub fn new(q: Q) -> Self {
        LaceRlPolicy {
            q,
            name: "lace-rl".to_string(),
            record: false,
            decisions: Vec::new(),
        }
    }

    pub fn recording(mut self) -> Self {
        self.record = true;
        self
    }

    pub fn q_mut(&mut self) -> &mut Q {
        &mut self.q
    }
}

impl<Q: QFunction> KeepAlivePolicy for LaceRlPolicy<Q> {
    fn name(&self) -> &str {
        &self.name
    }

    fn decide(&mut self, ctx: &DecisionContext) -> usize {
        let state = encode(ctx);
        let q = self.q.q_values(&state);
        let mut best = 0;
        let mut best_v = q[0];
        for (i, &v) in q.iter().enumerate().skip(1) {
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        if self.record {
            self.decisions.push(DecisionRecord { t: ctx.t, action: best, ci: ctx.ci });
        }
        best
    }

    fn fork(&self) -> Option<BoxedPolicy> {
        if self.record {
            // Recording runs keep all decisions on one instance.
            return None;
        }
        self.q.fork_policy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_util::{ctx, profile};

    /// Q-function with a fixed preference, independent of state.
    struct ConstQ([f32; 5]);
    impl QFunction for ConstQ {
        fn q_values(&mut self, _s: &[f32; STATE_DIM]) -> [f32; 5] {
            self.0
        }
    }

    #[test]
    fn greedy_argmax() {
        let f = profile(1.0);
        let c = ctx(&f, 300.0, [0.5; 5], 0.5);
        let mut p = LaceRlPolicy::new(ConstQ([0.0, 3.0, 1.0, 2.0, -1.0]));
        assert_eq!(p.decide(&c), 1);
    }

    #[test]
    fn recording_captures_decisions() {
        let f = profile(1.0);
        let c = ctx(&f, 420.0, [0.5; 5], 0.5);
        let mut p = LaceRlPolicy::new(ConstQ([1.0, 0.0, 0.0, 0.0, 0.0])).recording();
        p.decide(&c);
        p.decide(&c);
        assert_eq!(p.decisions.len(), 2);
        assert_eq!(p.decisions[0].action, 0);
        assert_eq!(p.decisions[0].ci, 420.0);
    }
}
