//! Latency-Minimizing baseline (§IV-A5): minimizes expected cold starts
//! regardless of energy cost. Since reuse probability is monotone in the
//! keep-alive duration, the expected cold cost (1 − p_k)·L_cold is
//! minimized by the longest timeout — the greedy over-provisioner the
//! paper shows exploding keep-alive carbon (Fig. 5c).

use crate::policy::{BoxedPolicy, DecisionContext, KeepAlivePolicy};
use crate::KEEP_ALIVE_ACTIONS;

/// Pre-warm horizon (s): Latency-Min retains pods an order of magnitude
/// beyond the action set's 60 s cap, the "indiscriminately prolonging
/// keep-alive durations" extreme of Fig. 5 whose keep-alive carbon dwarfs
/// every bounded policy.
pub const PREWARM_HORIZON_S: f64 = 600.0;

#[derive(Debug, Clone, Default)]
pub struct LatencyMin;

impl KeepAlivePolicy for LatencyMin {
    fn name(&self) -> &str {
        "latency-min"
    }

    fn decide(&mut self, ctx: &DecisionContext) -> usize {
        // argmin_k (1-p_k)·L_cold; ties broken toward the longest k
        // (monotone p_k makes this the last action in practice).
        let mut best = KEEP_ALIVE_ACTIONS.len() - 1;
        let mut best_cost = f64::INFINITY;
        for a in (0..KEEP_ALIVE_ACTIONS.len()).rev() {
            let cost = ctx.expected_cold_cost(a);
            if cost < best_cost {
                best_cost = cost;
                best = a;
            }
        }
        best
    }

    fn decide_seconds(&mut self, ctx: &DecisionContext) -> (usize, f64) {
        (self.decide(ctx), PREWARM_HORIZON_S)
    }

    fn fork(&self) -> Option<BoxedPolicy> {
        Some(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_util::{ctx, profile};

    #[test]
    fn picks_longest_under_monotone_probs() {
        let f = profile(2.0);
        let c = ctx(&f, 300.0, [0.1, 0.3, 0.5, 0.8, 0.95], 0.9);
        assert_eq!(LatencyMin.decide(&c), 4);
    }

    #[test]
    fn ignores_lambda_and_ci() {
        let f = profile(2.0);
        let lo = ctx(&f, 10.0, [0.2; 5], 0.0);
        let hi = ctx(&f, 900.0, [0.2; 5], 1.0);
        assert_eq!(LatencyMin.decide(&lo), LatencyMin.decide(&hi));
    }
}
