//! Keep-alive policies (paper §IV-A5) behind one trait.
//!
//! A policy is consulted once per invocation, at pod-completion time, and
//! returns the keep-alive timeout for that pod. The simulator resolves each
//! decision's *realized* outcome (reused vs expired, idle carbon accrued)
//! and reports it back via [`KeepAlivePolicy::observe`] — that feedback
//! channel is how the LACE-RL trainer collects transitions without the
//! simulator knowing anything about RL.

pub mod carbon_min;
pub mod dpso;
pub mod fixed;
pub mod lace_rl;
pub mod latency_min;
pub mod native_mlp;
pub mod oracle;

pub use carbon_min::CarbonMin;
pub use dpso::Dpso;
pub use fixed::FixedTimeout;
pub use lace_rl::LaceRlPolicy;
pub use latency_min::LatencyMin;
pub use oracle::Oracle;

use crate::trace::model::FunctionProfile;
use crate::KEEP_ALIVE_ACTIONS;

/// Everything a policy may observe at a decision point (paper Eq. 6 state,
/// plus the clairvoyant field only [`oracle::Oracle`] is allowed to read).
#[derive(Debug, Clone)]
pub struct DecisionContext<'a> {
    /// Decision time = pod completion time (seconds from trace start).
    pub t: f64,
    pub func: &'a FunctionProfile,
    /// Carbon intensity at `t` (gCO₂/kWh).
    pub ci: f64,
    /// P[pod reused within k] for each k in [`KEEP_ALIVE_ACTIONS`],
    /// estimated from the per-function sliding reuse window (§III-A).
    pub reuse_probs: [f64; 5],
    /// User trade-off weight λ_carbon ∈ [0,1] (§III-B).
    pub lambda_carbon: f64,
    /// λ_idle-scaled idle power of this pod (W) — lets policies price
    /// idle carbon without re-deriving the energy model.
    pub idle_power_w: f64,
    /// Time until this function's next arrival, measured from `t`.
    /// **Clairvoyant** — populated by the trace-driven simulator for the
    /// Oracle comparison (§IV-D); every other policy must ignore it.
    pub next_arrival_gap: Option<f64>,
}

impl<'a> DecisionContext<'a> {
    /// Expected cold-start cost C_cold(k) = (1 − p_k) · L_cold (§III-B).
    pub fn expected_cold_cost(&self, action: usize) -> f64 {
        (1.0 - self.reuse_probs[action]) * self.func.cold_start_s
    }

    /// Idle carbon cost C_carbon(k) = E_idle(k) · CI_t in grams (§III-B),
    /// charging the *full* timeout k (upper bound the agent reasons with).
    pub fn idle_carbon_cost(&self, action: usize) -> f64 {
        let k = KEEP_ALIVE_ACTIONS[action];
        self.idle_power_w * k * self.ci / crate::energy::JOULES_PER_KWH
    }
}

/// Realized outcome of a past decision, reported when it resolves.
#[derive(Debug, Clone, Copy)]
pub struct Outcome {
    pub func: u32,
    /// Index into [`KEEP_ALIVE_ACTIONS`] that was chosen.
    pub action: usize,
    /// Decision time.
    pub t: f64,
    /// Time the outcome resolved (reuse or observed expiry).
    pub resolved_t: f64,
    /// True if the pod was reused before its timeout elapsed.
    pub reused: bool,
    /// Idle span actually accrued (s): gap-to-reuse, or the full timeout.
    pub idle_span_s: f64,
    /// Idle carbon actually accrued over that span (g, CI-integrated).
    pub idle_carbon_g: f64,
    /// Cold-start latency charged to this decision (s): the cold start the
    /// expiry caused at the next arrival, 0 on reuse.
    pub cold_penalty_s: f64,
    /// True when resolved by end-of-trace flush (no next state exists).
    pub done: bool,
}

/// A heap-allocated policy that may cross thread boundaries (sweep cells,
/// simulation shards).
pub type BoxedPolicy = Box<dyn KeepAlivePolicy + Send>;

/// A keep-alive policy. `decide` returns an index into
/// [`KEEP_ALIVE_ACTIONS`].
///
/// ## The `fork()` contract (sharded simulation)
///
/// The per-function MDP (§III) makes every function's decisions independent
/// of every other function's, so `simulator::sharded::ShardedSimulator` can
/// replay disjoint function subsets on separate threads — *if* the policy
/// can hand each shard an instance whose per-function behaviour is
/// identical to its own. [`fork`](Self::fork) produces such an instance:
///
/// * **Stateless / config-only** policies (fixed timeouts, greedy
///   baselines, Oracle) fork by `Clone`.
/// * **Frozen-weight** policies (LACE-RL over [`native_mlp::NativeMlp`])
///   fork by sharing the weights behind an `Arc` — no deep copy.
/// * **Stochastic** policies (DPSO, the ε-greedy trainer agent) must derive
///   their randomness from per-function-id streams
///   ([`crate::util::rng::Rng::stream`]), so the sequence each function
///   sees is invariant under any shard count.
/// * Policies whose behaviour or collected state cannot be partitioned by
///   function (recording runs, PJRT-backed inference) return `None`, and
///   the sharded simulator falls back to a sequential run.
///
/// After the shards finish, [`absorb`](Self::absorb) is called on the
/// original once per fork, in shard (= ascending function-id) order, so
/// stateful policies can merge harvested state back deterministically.
pub trait KeepAlivePolicy {
    fn name(&self) -> &str;

    /// Choose a keep-alive action for the pod completing at `ctx.t`.
    fn decide(&mut self, ctx: &DecisionContext) -> usize;

    /// Action index *and* duration in seconds. Default maps through the
    /// discrete action set; baselines outside the set (Latency-Min's long
    /// pre-warm horizon) override the duration while still reporting the
    /// closest action index for outcome bookkeeping.
    fn decide_seconds(&mut self, ctx: &DecisionContext) -> (usize, f64) {
        let a = self.decide(ctx);
        (a, KEEP_ALIVE_ACTIONS[a])
    }

    /// Whether a reuse refreshes the pod's keep-alive timer. Adaptive
    /// policies re-arm the timer at every completion (true). The Huawei
    /// static baseline assigns its fixed 60 s window when the pod first
    /// idles and does not extend it on reuse — the non-adaptive behaviour
    /// that lets per-invocation policies beat it on *both* cold starts and
    /// idle carbon, matching the paper's Fig. 5 ordering (Latency-Min <
    /// LACE-RL < DPSO < Huawei on cold starts). See DESIGN.md §7.
    fn refreshes_timer(&self) -> bool {
        true
    }

    /// Feedback when a past decision resolves. Default: ignore.
    fn observe(&mut self, _outcome: &Outcome) {}

    /// Produce a shard-local instance for parallel replay (see the trait
    /// docs for the contract). Default: `None` — the sharded simulator
    /// falls back to a sequential run.
    fn fork(&self) -> Option<BoxedPolicy> {
        None
    }

    /// Merge state harvested by a fork back into the original. Called once
    /// per fork, in shard order, after all shards finish. Default: no-op
    /// (stateless forks have nothing to return).
    fn absorb(&mut self, _fork: &mut (dyn KeepAlivePolicy + Send)) {}

    /// Downcast hook for [`absorb`](Self::absorb) implementations that need
    /// the fork's concrete type. Default: `None`.
    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }
}

/// Convert an action index to seconds.
#[inline]
pub fn action_seconds(action: usize) -> f64 {
    KEEP_ALIVE_ACTIONS[action]
}

/// Latency-equivalent seconds per gram of CO₂ in the blended cost.
///
/// The paper's reward (Eq. 5) sums a latency term (seconds) and a carbon
/// term (grams) without stating a unit conversion; for λ_carbon to act as a
/// meaningful dial the two terms must be of comparable magnitude. A single
/// idle pod at 60 s keep-alive emits O(10⁻²) g while cold starts cost
/// O(0.1–10) s, so we price carbon at 25 s/g — calibrated so that at
/// λ = 0.5 a full 60 s retention (~0.008 g at 400 g/kWh) costs ≈0.2
/// latency-equivalent seconds: retention pays off whenever reuse is
/// plausible, while λ → 1 still reclaims aggressively. This positions
/// LACE-RL between Latency-Min and DPSO on cold starts at λ = 0.5 while
/// beating the static 60 s window on both axes (Fig. 5). Documented
/// reproduction decision (DESIGN.md §6); `experiments::fig10` sweeps λ to
/// show the dial behaves as in the paper.
pub const CARBON_COST_SCALE: f64 = 25.0;

/// Blended cost of Eq. 5: (1−λ)·C_cold + λ·κ·C_carbon. The reward used by
/// the RL trainer (and the objective Oracle/DPSO optimize) is its negation.
#[inline]
pub fn blended_cost(lambda_carbon: f64, cold_s: f64, carbon_g: f64) -> f64 {
    (1.0 - lambda_carbon) * cold_s + lambda_carbon * CARBON_COST_SCALE * carbon_g
}

#[cfg(test)]
pub(crate) mod test_util {
    use super::*;
    use crate::trace::model::{Runtime, TriggerType};

    pub fn profile(cold_start_s: f64) -> FunctionProfile {
        FunctionProfile {
            id: 0,
            runtime: Runtime::Python,
            trigger: TriggerType::Http,
            mem_mb: 64.0,
            cpu_cores: 1.0,
            cold_start_s,
            mean_exec_s: 0.2,
        }
    }

    pub fn ctx<'a>(
        func: &'a FunctionProfile,
        ci: f64,
        reuse_probs: [f64; 5],
        lambda: f64,
    ) -> DecisionContext<'a> {
        DecisionContext {
            t: 0.0,
            func,
            ci,
            reuse_probs,
            lambda_carbon: lambda,
            idle_power_w: 1.2,
            next_arrival_gap: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_util::*;
    use super::*;

    #[test]
    fn expected_cold_cost_shrinks_with_reuse_prob() {
        let f = profile(2.0);
        let c = ctx(&f, 300.0, [0.0, 0.2, 0.5, 0.9, 1.0], 0.5);
        assert_eq!(c.expected_cold_cost(0), 2.0);
        assert!((c.expected_cold_cost(2) - 1.0).abs() < 1e-12);
        assert_eq!(c.expected_cold_cost(4), 0.0);
    }

    #[test]
    fn idle_cost_grows_with_action() {
        let f = profile(2.0);
        let c = ctx(&f, 300.0, [0.5; 5], 0.5);
        let costs: Vec<f64> = (0..5).map(|a| c.idle_carbon_cost(a)).collect();
        for w in costs.windows(2) {
            assert!(w[1] > w[0]);
        }
        // 60s at 1.2 W, 300 g/kWh: 1.2*60*300/3.6e6 = 0.006 g
        assert!((costs[4] - 0.006).abs() < 1e-12);
    }
}
