//! Pure-Rust Q-network forward pass — the perf-pass fast path.
//!
//! Runs the same 3-layer MLP as the AOT `dqn_infer` artifact, on weights
//! exported after training ([`crate::rl::weights`]). Used where a single
//! decision must cost ~1 µs (the paper's 15 µs/invocation claim, §IV-E);
//! agreement with the PJRT executable is asserted to 1e-5 in the
//! integration tests.

use crate::rl::qnet::QNetParams;
use crate::util::gemm::{linear, linear_relu};
use std::sync::Arc;

/// f32 MLP: input `d_in` → relu(h1) → relu(h2) → `d_out`.
///
/// Weights live behind an `Arc` so forks (shard-local policies, the
/// trainer's per-episode agent refresh) share one frozen copy instead of
/// deep-cloning O(10k) floats; only the small scratch buffers are per
/// instance.
#[derive(Debug, Clone)]
pub struct NativeMlp {
    params: Arc<QNetParams>,
    // Scratch buffers: no allocation on the per-decision hot path.
    h1: Vec<f32>,
    h2: Vec<f32>,
    out: Vec<f32>,
}

impl NativeMlp {
    pub fn new(params: QNetParams) -> Self {
        Self::from_arc(Arc::new(params))
    }

    /// Build on already-shared weights (no copy).
    pub fn from_arc(params: Arc<QNetParams>) -> Self {
        let h1 = vec![0.0; params.hidden1()];
        let h2 = vec![0.0; params.hidden2()];
        let out = vec![0.0; params.n_actions()];
        NativeMlp { params, h1, h2, out }
    }

    pub fn params(&self) -> &QNetParams {
        &self.params
    }

    /// Shared handle to the weights (for forking without a deep copy).
    pub fn params_arc(&self) -> Arc<QNetParams> {
        Arc::clone(&self.params)
    }

    /// Swap in new weights, reusing the scratch buffers when the
    /// architecture is unchanged (the per-episode trainer path).
    pub fn set_params(&mut self, params: Arc<QNetParams>) {
        if params.dims != self.params.dims {
            self.h1.resize(params.hidden1(), 0.0);
            self.h2.resize(params.hidden2(), 0.0);
            self.out.resize(params.n_actions(), 0.0);
        }
        self.params = params;
    }

    /// Forward pass; returns the Q-value slice (valid until next call).
    pub fn forward(&mut self, state: &[f32]) -> &[f32] {
        let p = &self.params;
        debug_assert_eq!(state.len(), p.state_dim());
        linear_relu(state, &p.w1, &p.b1, &mut self.h1);
        linear_relu(&self.h1, &p.w2, &p.b2, &mut self.h2);
        linear(&self.h2, &p.w3, &p.b3, &mut self.out);
        &self.out
    }

    /// Greedy action (argmax over Q).
    pub fn argmax(&mut self, state: &[f32]) -> usize {
        let q = self.forward(state);
        let mut best = 0;
        let mut best_v = q[0];
        for (i, &v) in q.iter().enumerate().skip(1) {
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::qnet::QNetParams;

    /// 2 -> 2 -> 2 -> 2 identity-ish network for hand-checkable numbers.
    fn tiny() -> QNetParams {
        QNetParams {
            dims: (2, 2, 2, 2),
            w1: vec![1.0, 0.0, 0.0, 1.0],
            b1: vec![0.0, 0.0],
            w2: vec![1.0, 0.0, 0.0, 1.0],
            b2: vec![0.0, 0.0],
            w3: vec![1.0, 0.0, 0.0, 1.0],
            b3: vec![0.5, -0.5],
        }
    }

    #[test]
    fn identity_network_passes_through() {
        let mut mlp = NativeMlp::new(tiny());
        let q = mlp.forward(&[2.0, 3.0]);
        assert_eq!(q, &[2.5, 2.5]);
    }

    #[test]
    fn relu_clips_negatives() {
        let mut p = tiny();
        p.b1 = vec![-10.0, 0.0]; // first hidden unit always clipped
        let mut mlp = NativeMlp::new(p);
        let q = mlp.forward(&[2.0, 3.0]);
        assert_eq!(q, &[0.5, 2.5]);
    }

    #[test]
    fn argmax_picks_largest() {
        let mut mlp = NativeMlp::new(tiny());
        assert_eq!(mlp.argmax(&[1.0, 5.0]), 1);
        assert_eq!(mlp.argmax(&[5.0, 1.0]), 0);
    }

    #[test]
    fn matches_manual_matmul_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let (d_in, h1, h2, d_out) = (10, 64, 64, 5);
        let mk = |n: usize, rng: &mut Rng| -> Vec<f32> {
            (0..n).map(|_| rng.normal(0.0, 0.3) as f32).collect()
        };
        let p = QNetParams {
            dims: (d_in, h1, h2, d_out),
            w1: mk(d_in * h1, &mut rng),
            b1: mk(h1, &mut rng),
            w2: mk(h1 * h2, &mut rng),
            b2: mk(h2, &mut rng),
            w3: mk(h2 * d_out, &mut rng),
            b3: mk(d_out, &mut rng),
        };
        let x: Vec<f32> = (0..d_in).map(|_| rng.normal(0.0, 1.0) as f32).collect();

        // Reference: straightforward f64 matmul.
        let dense = |x: &[f64], w: &[f32], b: &[f32], n_out: usize, relu: bool| {
            let mut y = vec![0.0f64; n_out];
            for j in 0..n_out {
                let mut acc = b[j] as f64;
                for (i, &xi) in x.iter().enumerate() {
                    acc += xi * w[i * n_out + j] as f64;
                }
                y[j] = if relu { acc.max(0.0) } else { acc };
            }
            y
        };
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let r1 = dense(&x64, &p.w1, &p.b1, h1, true);
        let r2 = dense(&r1, &p.w2, &p.b2, h2, true);
        let want = dense(&r2, &p.w3, &p.b3, d_out, false);

        let mut mlp = NativeMlp::new(p);
        let got = mlp.forward(&x);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((*g as f64 - w).abs() < 1e-4, "{g} vs {w}");
        }
    }
}
