//! Oracle policy (§IV-D): perfect future knowledge of the next arrival.
//!
//! The only policy permitted to read `DecisionContext::next_arrival_gap`
//! (populated by the simulator when `provide_oracle_gap` is set). For each
//! decision it evaluates the *realized* blended cost of every action:
//!
//! * action k ≥ gap → pod reused: cost = λ·κ·carbon(idle over gap)
//! * action k < gap → pod expires: cost = λ·κ·carbon(idle over k) +
//!   (1−λ)·L_cold (the cold start the expiry causes)
//!
//! and picks the argmin — the per-decision optimum, hence the theoretical
//! limit LACE-RL is measured against (Table III).

use crate::energy::JOULES_PER_KWH;
use crate::policy::{blended_cost, BoxedPolicy, DecisionContext, KeepAlivePolicy};
use crate::KEEP_ALIVE_ACTIONS;

#[derive(Debug, Clone, Default)]
pub struct Oracle;

impl Oracle {
    fn idle_carbon(ctx: &DecisionContext, span_s: f64) -> f64 {
        // CI held at the decision-time value; the simulator integrates the
        // true trace, but for action ranking the hour-scale constancy
        // assumption (§II-B) is exactly the paper's.
        ctx.idle_power_w * span_s * ctx.ci / JOULES_PER_KWH
    }
}

impl KeepAlivePolicy for Oracle {
    fn name(&self) -> &str {
        "oracle"
    }

    fn decide(&mut self, ctx: &DecisionContext) -> usize {
        let gap = match ctx.next_arrival_gap {
            // No future arrival: any retention is pure waste.
            None => return 0,
            Some(g) => g,
        };
        let mut best = 0;
        let mut best_cost = f64::INFINITY;
        for (a, &k) in KEEP_ALIVE_ACTIONS.iter().enumerate() {
            let cost = if k >= gap {
                blended_cost(ctx.lambda_carbon, 0.0, Self::idle_carbon(ctx, gap))
            } else {
                blended_cost(
                    ctx.lambda_carbon,
                    ctx.func.cold_start_s,
                    Self::idle_carbon(ctx, k),
                )
            };
            if cost < best_cost {
                best_cost = cost;
                best = a;
            }
        }
        best
    }

    fn fork(&self) -> Option<BoxedPolicy> {
        Some(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_util::{ctx, profile};

    fn with_gap(cold_s: f64, lambda: f64, gap: Option<f64>, ci: f64) -> usize {
        let f = profile(cold_s);
        let mut c = ctx(&f, ci, [0.5; 5], lambda);
        c.next_arrival_gap = gap;
        Oracle.decide(&c)
    }

    #[test]
    fn keeps_smallest_sufficient_k() {
        // gap 8s, expensive cold start: keep with k=10 (smallest ≥ 8).
        let a = with_gap(5.0, 0.5, Some(8.0), 300.0);
        assert_eq!(KEEP_ALIVE_ACTIONS[a], 10.0);
    }

    #[test]
    fn drops_when_cold_start_cheap_and_carbon_pricey() {
        // Tiny cold start, pure carbon objective: expire immediately.
        let a = with_gap(0.01, 1.0, Some(50.0), 900.0);
        assert_eq!(KEEP_ALIVE_ACTIONS[a], 1.0);
    }

    #[test]
    fn no_future_arrival_shortest() {
        assert_eq!(with_gap(10.0, 0.0, None, 300.0), 0);
    }

    #[test]
    fn pure_latency_objective_always_bridges() {
        // λ=0: idle carbon free, always pick a k covering the gap.
        let a = with_gap(0.5, 0.0, Some(25.0), 900.0);
        assert!(KEEP_ALIVE_ACTIONS[a] >= 25.0);
    }

    #[test]
    fn unbridgeable_gap_wastes_nothing() {
        // gap 1000s > 60s: every k expires; minimum idle waste wins.
        let a = with_gap(5.0, 0.5, Some(1000.0), 300.0);
        assert_eq!(KEEP_ALIVE_ACTIONS[a], 1.0);
    }
}
