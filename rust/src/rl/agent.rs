//! ε-greedy training agent: a [`KeepAlivePolicy`] that explores, harvests
//! realized outcomes from the simulator and assembles MDP transitions.
//!
//! Transition chaining (§III-C): each decision for function *f* becomes a
//! transition whose `next_state` is the state at *f*'s next decision — the
//! per-function MDP the paper formulates. The simulator guarantees
//! `observe(outcome)` for a decision fires before the same function's next
//! `decide`, so the agent:
//!
//! 1. on `decide`: completes every *resolved* pending transition of this
//!    function using the fresh state as `next_state`, then records the new
//!    (state, action) as pending;
//! 2. on `observe`: attaches the realized reward
//!    `R = −[(1−λ)·cold_penalty + λ·κ·idle_carbon] · scale` to the matching
//!    pending entry; `done` outcomes complete immediately with a zeroed
//!    terminal state.

use std::collections::HashMap;

use crate::policy::native_mlp::NativeMlp;
use crate::policy::{blended_cost, BoxedPolicy, DecisionContext, KeepAlivePolicy, Outcome};
use crate::rl::encoder::{encode, STATE_DIM};
use crate::rl::replay::Transition;
use crate::util::rng::Rng;

/// Rewards are scaled down so early TD targets stay in the Huber-quadratic
/// regime (|R| ≲ a few units).
pub const REWARD_SCALE: f64 = 0.1;

#[derive(Debug, Clone, Copy)]
struct PendingT {
    state: [f32; STATE_DIM],
    action: u8,
    decision_t: f64,
    reward: Option<f32>,
}

/// The exploring agent. Owns the current online network copy for greedy
/// actions; exploration is ε-uniform.
///
/// Exploration randomness is drawn from one [`Rng::stream`] per function
/// id, so the action sequence each function sees depends only on its own
/// decision count — invariant under sharding the trace across threads
/// (`simulator::sharded`). Harvested transitions are tagged with their
/// function id and canonicalized (stable-sorted by function) on drain, so
/// the replay stream is likewise shard-count-invariant.
pub struct EpsilonGreedyAgent {
    mlp: NativeMlp,
    pub epsilon: f64,
    base_seed: u64,
    streams: HashMap<u32, Rng>,
    pending: HashMap<u32, Vec<PendingT>>,
    /// Completed transitions, tagged by function id; drained (canonically
    /// ordered) by the trainer after each episode.
    transitions: Vec<(u32, Transition)>,
    /// Episode reward accumulator (diagnostics).
    pub episode_reward: f64,
    pub decisions: u64,
    /// λ seen at the last decide() — outcomes lack the weight, contexts
    /// carry it. Defaults to 0.5 until the first decision.
    last_lambda: f64,
}

impl EpsilonGreedyAgent {
    pub fn new(mlp: NativeMlp, epsilon: f64, seed: u64) -> Self {
        EpsilonGreedyAgent {
            mlp,
            epsilon,
            base_seed: seed,
            streams: HashMap::new(),
            pending: HashMap::new(),
            transitions: Vec::new(),
            episode_reward: 0.0,
            decisions: 0,
            last_lambda: 0.5,
        }
    }

    /// Swap in fresh online weights (between episodes).
    pub fn set_mlp(&mut self, mlp: NativeMlp) {
        self.mlp = mlp;
    }

    /// Re-derive all per-function exploration streams from a new seed.
    pub fn reseed(&mut self, seed: u64) {
        self.base_seed = seed;
        self.streams.clear();
    }

    /// Number of completed transitions awaiting drain.
    pub fn harvested(&self) -> usize {
        self.transitions.len()
    }

    /// Drain harvested transitions in canonical (function-id) order.
    /// Within a function, completion order is already shard-invariant; the
    /// stable sort makes the cross-function interleaving so too.
    pub fn take_transitions(&mut self) -> Vec<Transition> {
        let mut tagged = std::mem::take(&mut self.transitions);
        tagged.sort_by_key(|(f, _)| *f);
        tagged.into_iter().map(|(_, t)| t).collect()
    }

    /// Drop unresolved pendings and reset per-episode counters. Keeps the
    /// map capacity (the trainer reuses one agent across episodes).
    pub fn reset_episode(&mut self) {
        self.pending.clear();
        self.episode_reward = 0.0;
        self.decisions = 0;
    }

    fn reward_of(outcome: &Outcome, lambda: f64) -> f32 {
        (-blended_cost(lambda, outcome.cold_penalty_s, outcome.idle_carbon_g)
            * REWARD_SCALE) as f32
    }

    /// λ used for reward shaping — the simulator's configured λ is also in
    /// the state vector, so the agent reads it from the context at decide
    /// time and caches it here for observe time.
    fn lambda(&self) -> f64 {
        self.last_lambda
    }
}

impl KeepAlivePolicy for EpsilonGreedyAgent {
    fn name(&self) -> &str {
        "epsilon-greedy-agent"
    }

    fn decide(&mut self, ctx: &DecisionContext) -> usize {
        self.last_lambda = ctx.lambda_carbon;
        let state = encode(ctx);

        // Complete resolved pendings of this function: their next_state is
        // exactly this state.
        if let Some(list) = self.pending.get_mut(&ctx.func.id) {
            let mut i = 0;
            while i < list.len() {
                if let Some(reward) = list[i].reward {
                    let p = list.swap_remove(i);
                    self.transitions.push((
                        ctx.func.id,
                        Transition {
                            state: p.state,
                            action: p.action,
                            reward,
                            next_state: state,
                            done: false,
                        },
                    ));
                } else {
                    i += 1;
                }
            }
        }

        // ε-greedy action from this function's own stream.
        let epsilon = self.epsilon;
        let base_seed = self.base_seed;
        let rng = self
            .streams
            .entry(ctx.func.id)
            .or_insert_with(|| Rng::stream(base_seed, ctx.func.id as u64));
        let action = if rng.chance(epsilon) {
            rng.index(5)
        } else {
            self.mlp.argmax(&state)
        };
        self.decisions += 1;

        self.pending.entry(ctx.func.id).or_default().push(PendingT {
            state,
            action: action as u8,
            decision_t: ctx.t,
            reward: None,
        });
        action
    }

    fn observe(&mut self, outcome: &Outcome) {
        let reward = Self::reward_of(outcome, self.lambda());
        self.episode_reward += reward as f64;
        let Some(list) = self.pending.get_mut(&outcome.func) else {
            return;
        };
        let Some(idx) = list
            .iter()
            .position(|p| p.decision_t == outcome.t && p.action as usize == outcome.action)
        else {
            return;
        };
        if outcome.done {
            let p = list.swap_remove(idx);
            self.transitions.push((
                outcome.func,
                Transition {
                    state: p.state,
                    action: p.action,
                    reward,
                    next_state: [0.0; STATE_DIM],
                    done: true,
                },
            ));
        } else {
            list[idx].reward = Some(reward);
        }
    }

    fn fork(&self) -> Option<BoxedPolicy> {
        // Same weights (Arc-shared), same base seed: each function's
        // exploration stream is re-derived identically on the shard.
        Some(Box::new(EpsilonGreedyAgent::new(
            NativeMlp::from_arc(self.mlp.params_arc()),
            self.epsilon,
            self.base_seed,
        )))
    }

    fn absorb(&mut self, fork: &mut (dyn KeepAlivePolicy + Send)) {
        let Some(fork) = fork
            .as_any_mut()
            .and_then(|a| a.downcast_mut::<EpsilonGreedyAgent>())
        else {
            return;
        };
        self.transitions.append(&mut fork.transitions);
        self.episode_reward += fork.episode_reward;
        self.decisions += fork.decisions;
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_util::{ctx, profile};
    use crate::rl::qnet::QNetParams;

    fn agent(epsilon: f64) -> EpsilonGreedyAgent {
        let p = QNetParams::zeros((STATE_DIM, 8, 8, 5));
        EpsilonGreedyAgent::new(NativeMlp::new(p), epsilon, 42)
    }

    fn outcome(func: u32, t: f64, action: usize, done: bool) -> Outcome {
        Outcome {
            func,
            action,
            t,
            resolved_t: t + 1.0,
            reused: false,
            idle_span_s: 1.0,
            idle_carbon_g: 0.001,
            cold_penalty_s: 2.0,
            done,
        }
    }

    #[test]
    fn chains_transition_to_next_decide() {
        let f = profile(2.0);
        let mut a = agent(0.0);
        let c1 = {
            let mut c = ctx(&f, 300.0, [0.1; 5], 0.5);
            c.t = 10.0;
            c
        };
        let act = a.decide(&c1);
        a.observe(&outcome(0, 10.0, act, false));
        assert_eq!(a.harvested(), 0); // awaits next state
        let c2 = {
            let mut c = ctx(&f, 300.0, [0.9; 5], 0.5);
            c.t = 20.0;
            c
        };
        a.decide(&c2);
        let ts = a.take_transitions();
        assert_eq!(ts.len(), 1);
        let t = &ts[0];
        assert!(!t.done);
        assert!((t.next_state[0] - 0.9).abs() < 1e-6); // state at second decide
        // reward = -[(0.5·2.0) + 0.5·κ·0.001] · 0.1 with κ = CARBON_COST_SCALE
        let want = -(0.5 * 2.0 + 0.5 * crate::policy::CARBON_COST_SCALE * 0.001) * 0.1;
        assert!((t.reward as f64 - want).abs() < 1e-6, "r={} want={want}", t.reward);
    }

    #[test]
    fn done_outcome_completes_immediately() {
        let f = profile(2.0);
        let mut a = agent(0.0);
        let mut c = ctx(&f, 300.0, [0.1; 5], 0.5);
        c.t = 5.0;
        let act = a.decide(&c);
        a.observe(&outcome(0, 5.0, act, true));
        let ts = a.take_transitions();
        assert_eq!(ts.len(), 1);
        assert!(ts[0].done);
        assert_eq!(ts[0].next_state, [0.0; STATE_DIM]);
    }

    #[test]
    fn epsilon_one_explores_uniformly() {
        let f = profile(2.0);
        let mut a = agent(1.0);
        let mut seen = [0usize; 5];
        for i in 0..500 {
            let mut c = ctx(&f, 300.0, [0.1; 5], 0.5);
            c.t = i as f64;
            seen[a.decide(&c)] += 1;
        }
        for s in seen {
            assert!(s > 50, "{seen:?}");
        }
    }

    #[test]
    fn epsilon_zero_is_greedy_deterministic() {
        let f = profile(2.0);
        let mut a = agent(0.0);
        let c = ctx(&f, 300.0, [0.1; 5], 0.5);
        let first = a.decide(&c);
        for _ in 0..10 {
            assert_eq!(a.decide(&c), first);
        }
    }

    #[test]
    fn unmatched_outcome_ignored() {
        let mut a = agent(0.0);
        a.observe(&outcome(99, 1.0, 0, false));
        assert_eq!(a.harvested(), 0);
    }

    #[test]
    fn reset_drops_pendings() {
        let f = profile(2.0);
        let mut a = agent(0.0);
        let c = ctx(&f, 300.0, [0.1; 5], 0.5);
        a.decide(&c);
        a.reset_episode();
        assert_eq!(a.decisions, 0);
        // Outcome for the dropped pending is ignored.
        a.observe(&outcome(0, 0.0, 0, false));
        assert_eq!(a.harvested(), 0);
    }

    #[test]
    fn take_transitions_canonical_order() {
        let mut f1 = profile(2.0);
        f1.id = 1;
        let f0 = profile(2.0);
        let mut a = agent(0.0);
        // Interleave: decide f1, decide f0, resolve & complete both.
        for (f, t0) in [(&f1, 0.0), (&f0, 1.0)] {
            let mut c = ctx(f, 300.0, [0.1; 5], 0.5);
            c.t = t0;
            let act = a.decide(&c);
            a.observe(&outcome(f.id, t0, act, true));
        }
        let ts = a.take_transitions();
        assert_eq!(ts.len(), 2);
        // f0's transition drains before f1's despite completing later.
        // (Identify by nothing else: states are equal here, so re-run with
        // distinct rewards via different cold penalties.)
        let mut b = agent(0.0);
        for (f, t0, cold) in [(&f1, 0.0, 4.0), (&f0, 1.0, 2.0)] {
            let mut c = ctx(f, 300.0, [0.1; 5], 0.5);
            c.t = t0;
            let act = b.decide(&c);
            let mut o = outcome(f.id, t0, act, true);
            o.cold_penalty_s = cold;
            b.observe(&o);
        }
        let ts = b.take_transitions();
        assert!(ts[0].reward > ts[1].reward, "f0 (cheaper cold) must drain first");
    }

    #[test]
    fn exploration_invariant_under_function_interleaving() {
        let f0 = profile(2.0);
        let mut f1 = profile(2.0);
        f1.id = 1;
        let mut inter = agent(1.0);
        let mut alone = agent(1.0);
        let mut got = Vec::new();
        let mut want = Vec::new();
        for i in 0..50 {
            let mut c0 = ctx(&f0, 300.0, [0.1; 5], 0.5);
            c0.t = i as f64;
            let mut c1 = ctx(&f1, 300.0, [0.1; 5], 0.5);
            c1.t = i as f64 + 0.5;
            inter.decide(&c0);
            got.push(inter.decide(&c1));
            want.push(alone.decide(&c1));
        }
        assert_eq!(got, want);
    }
}
