//! Pluggable gradient-step backends for the DQN trainer.
//!
//! The trainer's hot loop ([`crate::rl::trainer`]) is backend-agnostic: it
//! samples a [`SampleBatch`] and hands it to a [`TrainBackend`], which owns
//! the online/target parameters and the Adam moments. Two implementations
//! exist:
//!
//! - [`crate::rl::native_train::NativeBackend`] — pure-Rust batched
//!   GEMM forward/backward + in-place Adam; zero allocations per step, no
//!   artifacts required, bit-identical across reruns.
//! - [`crate::runtime::backend::PjrtBackend`] — the AOT-compiled
//!   `dqn_train_step` executable; requires the artifact set on disk.
//!
//! The two agree to ≤1e-5 on params and loss over ≥100 steps (see
//! `rust/tests/property_native_train.rs`); DESIGN.md §11 records the
//! numerics contract.

use crate::rl::qnet::QNetParams;
use crate::rl::replay::SampleBatch;
use std::sync::Arc;

/// Which gradient-step engine the trainer drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust batched train step (`rl::native_train`); no artifacts.
    Native,
    /// AOT-compiled PJRT `dqn_train_step` executable.
    Pjrt,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => anyhow::bail!("unknown backend '{other}' (expected 'native' or 'pjrt')"),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One DQN gradient step plus target-network bookkeeping.
///
/// Contract (mirrors `python/compile/model.py::dqn_train_step`):
/// `step` must apply exactly one Adam update — TD targets from the target
/// net (`r + γ·(1−done)·max_a' Q'(s')`), mean Huber loss over the batch on
/// the chosen-action Q values, gradients through the online net only —
/// and return the scalar loss. `t` is the 1-based Adam timestep used for
/// bias correction.
pub trait TrainBackend {
    /// Human-readable backend name (obs metadata, logs).
    fn name(&self) -> &'static str;

    /// Run one gradient step on `batch`; returns the Huber loss.
    fn step(&mut self, t: u64, batch: &SampleBatch) -> anyhow::Result<f32>;

    /// Copy the online parameters into the target network.
    fn sync_target(&mut self);

    /// Shared snapshot of the current online parameters (for the rollout
    /// agent's per-episode refresh). Called once per episode, so a clone
    /// here is off the gradient hot path.
    fn snapshot(&self) -> Arc<QNetParams>;

    /// Borrow the current online parameters (final-weights export, tests).
    fn params(&self) -> &QNetParams;
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn backend_kind_round_trips() {
        for kind in [BackendKind::Native, BackendKind::Pjrt] {
            assert_eq!(BackendKind::from_str(kind.as_str()).unwrap(), kind);
            assert_eq!(format!("{kind}"), kind.as_str());
        }
        assert!(BackendKind::from_str("tpu").is_err());
    }
}
