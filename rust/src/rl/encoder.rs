//! State encoder (paper Eq. 6 / §III-A).
//!
//! Maps a [`DecisionContext`] to the 10-dim feature vector the DQN
//! consumes: `[p_k1..p_k5, mem, cpu, L_cold, CI, λ_carbon]`.
//!
//! Normalization is *fixed and deterministic* (no training-set statistics
//! to ship): long-tailed features (memory, cold-start latency) are
//! log-compressed as the paper prescribes, bounded features are scaled to
//! [0, 1]. The same function runs at train and inference time on both the
//! Rust native path and in the values fed to the PJRT executables, so
//! train/serve skew is structurally impossible.

use crate::policy::DecisionContext;

/// Input dimensionality — must equal model.py's STATE_DIM.
pub const STATE_DIM: usize = 10;

/// Normalization caps (values clamp at 1.0 beyond these).
pub const MEM_CAP_MB: f64 = 4096.0;
pub const CPU_CAP_CORES: f64 = 4.0;
pub const COLD_CAP_S: f64 = 20.0;
pub const CI_CAP: f64 = 1000.0;

/// Encode a decision context into the DQN state vector.
#[inline]
pub fn encode(ctx: &DecisionContext) -> [f32; STATE_DIM] {
    let mut s = [0.0f32; STATE_DIM];
    for i in 0..5 {
        s[i] = ctx.reuse_probs[i] as f32;
    }
    s[5] = log_norm(ctx.func.mem_mb, MEM_CAP_MB);
    s[6] = (ctx.func.cpu_cores / CPU_CAP_CORES).clamp(0.0, 1.0) as f32;
    s[7] = log_norm(ctx.func.cold_start_s, COLD_CAP_S);
    s[8] = (ctx.ci / CI_CAP).clamp(0.0, 1.0) as f32;
    s[9] = ctx.lambda_carbon as f32;
    s
}

/// ln(1+x)/ln(1+cap), clamped to [0, 1] — the paper's log-normalization
/// for long-tailed features.
#[inline]
fn log_norm(x: f64, cap: f64) -> f32 {
    ((1.0 + x.max(0.0)).ln() / (1.0 + cap).ln()).clamp(0.0, 1.0) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::test_util::{ctx, profile};

    #[test]
    fn layout_matches_eq6() {
        let f = profile(2.0);
        let c = ctx(&f, 500.0, [0.1, 0.2, 0.3, 0.4, 0.5], 0.7);
        let s = encode(&c);
        assert_eq!(&s[0..5], &[0.1, 0.2, 0.3, 0.4, 0.5]);
        assert!((s[8] - 0.5).abs() < 1e-6); // CI 500/1000
        assert!((s[9] - 0.7).abs() < 1e-6); // lambda
    }

    #[test]
    fn all_features_bounded() {
        let mut f = profile(1e9);
        f.mem_mb = 1e9;
        f.cpu_cores = 1e9;
        let c = ctx(&f, 1e9, [1.0; 5], 1.0);
        let s = encode(&c);
        for v in s {
            assert!((0.0..=1.0).contains(&v), "{s:?}");
        }
    }

    #[test]
    fn log_norm_is_monotone_and_compresses() {
        let a = log_norm(0.1, 20.0);
        let b = log_norm(1.0, 20.0);
        let c = log_norm(10.0, 20.0);
        assert!(a < b && b < c && c < 1.0);
        // Compression: 10x input gives much less than 10x feature.
        assert!(c / b < 5.0);
    }

    #[test]
    fn zero_inputs_zero_features() {
        let mut f = profile(0.0);
        f.mem_mb = 0.0;
        f.cpu_cores = 0.0;
        let c = ctx(&f, 0.0, [0.0; 5], 0.0);
        let s = encode(&c);
        assert!(s.iter().all(|&v| v == 0.0));
    }
}
