//! The LACE-RL learning stack (paper §III).
//!
//! Everything RL lives here: the state encoder (Eq. 6), the replay buffer,
//! the ε-greedy training policy that harvests transitions from simulator
//! feedback, the Rust-side DQN trainer that drives the AOT-compiled
//! `dqn_train_step` executable via PJRT, and weight serialization shared
//! with the Python build path.

pub mod agent;
pub mod encoder;
pub mod qnet;
pub mod replay;
pub mod trainer;
pub mod weights;

pub use encoder::{encode, STATE_DIM};
pub use qnet::QNetParams;
pub use replay::{ReplayBuffer, Transition};
