//! The LACE-RL learning stack (paper §III).
//!
//! Everything RL lives here: the state encoder (Eq. 6), the replay buffer,
//! the ε-greedy training policy that harvests transitions from simulator
//! feedback, the backend-agnostic DQN trainer ([`trainer`]) with its two
//! gradient engines — the AOT-compiled PJRT `dqn_train_step` executable
//! and the pure-Rust batched step ([`native_train`]) — and weight
//! serialization shared with the Python build path.

pub mod agent;
pub mod backend;
pub mod encoder;
pub mod native_train;
pub mod qnet;
pub mod replay;
pub mod trainer;
pub mod weights;

pub use backend::{BackendKind, TrainBackend};
pub use encoder::{encode, STATE_DIM};
pub use native_train::NativeBackend;
pub use qnet::QNetParams;
pub use replay::{ReplayBuffer, SampleBatch, Transition};
