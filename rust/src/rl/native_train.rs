//! Pure-Rust batched DQN train step — the gradient-path fast path.
//!
//! Mirrors the AOT-compiled `dqn_train_step` (python/compile/model.py) end
//! to end: batched forward for the online and target nets as blocked GEMM
//! ([`crate::util::gemm`], the same 4-wide kernel the inference fast path
//! uses), TD targets + mean Huber loss, a hand-derived backward pass, and
//! in-place Adam on double-buffered parameter/moment tensors. All scratch
//! is preallocated in [`NativeTrainStep::new`], so one gradient step
//! performs **zero heap allocations** (asserted by the counting-allocator
//! test in `rust/tests/alloc_native_train.rs`).
//!
//! Numerics are written to track XLA bit-for-bit where cheap and to ≤1e-5
//! where not (see DESIGN.md §11):
//! - scalar constants like `1 − β₁` are folded in f64 and then cast to
//!   f32, exactly as XLA folds Python-float constants;
//! - ReLU's gradient at exactly 0 is 0.5, matching JAX's balanced
//!   `maximum` tie-breaking;
//! - the Adam update applies operations in the same order and
//!   associativity as the jaxpr (`p − (lr·m̂)/(√v̂ + ε)`).
//!
//! Cross-backend agreement with the PJRT executable is property-tested in
//! `rust/tests/property_native_train.rs`.

use crate::rl::backend::TrainBackend;
use crate::rl::qnet::QNetParams;
use crate::rl::replay::SampleBatch;
use crate::util::gemm::{gemm_bias, gemm_wt, grad_bias, grad_weights, relu};
use std::sync::Arc;

/// Hyper-parameters, identical to python/compile/model.py.
pub const GAMMA: f32 = 0.99;
pub const LR: f32 = 1e-3;
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;
pub const HUBER_DELTA: f32 = 1.0;
// Folded in f64 then cast, matching how XLA folds the Python-float
// expressions `1.0 - ADAM_B1` / `1.0 - ADAM_B2` before f32 weaving.
// (`1.0f32 - 0.9f32` has different bits — do not "simplify".)
const ONE_MINUS_B1: f32 = (1.0 - 0.9) as f32;
const ONE_MINUS_B2: f32 = (1.0 - 0.999) as f32;

/// Preallocated scratch for one batched gradient step.
///
/// Holds every intermediate the forward/backward pass needs (target-net
/// activations, online pre-activations + activations, error signals, and a
/// full gradient accumulator), sized once for a fixed `(dims, batch)`.
#[derive(Debug, Clone)]
pub struct NativeTrainStep {
    dims: (usize, usize, usize, usize),
    batch: usize,
    // Target-net forward (activations only — no gradients flow here).
    th1: Vec<f32>,
    th2: Vec<f32>,
    tq: Vec<f32>,
    targets: Vec<f32>,
    // Online forward: pre-activations z* are kept for the ReLU gradient
    // (a==0 cannot distinguish z<0 from the z==0 half-gradient tie).
    z1: Vec<f32>,
    a1: Vec<f32>,
    z2: Vec<f32>,
    a2: Vec<f32>,
    q: Vec<f32>,
    // Backward error signals and gradient accumulator.
    dq: Vec<f32>,
    dh2: Vec<f32>,
    dh1: Vec<f32>,
    g: QNetParams,
}

impl NativeTrainStep {
    pub fn new(dims: (usize, usize, usize, usize), batch: usize) -> Self {
        assert!(batch > 0);
        let (d, h1, h2, a) = dims;
        debug_assert!(d > 0 && h1 > 0 && h2 > 0 && a > 0);
        NativeTrainStep {
            dims,
            batch,
            th1: vec![0.0; batch * h1],
            th2: vec![0.0; batch * h2],
            tq: vec![0.0; batch * a],
            targets: vec![0.0; batch],
            z1: vec![0.0; batch * h1],
            a1: vec![0.0; batch * h1],
            z2: vec![0.0; batch * h2],
            a2: vec![0.0; batch * h2],
            q: vec![0.0; batch * a],
            dq: vec![0.0; batch * a],
            dh2: vec![0.0; batch * h2],
            dh1: vec![0.0; batch * h1],
            g: QNetParams::zeros(dims),
        }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// One gradient step: TD targets from `target`, mean Huber loss on the
    /// chosen-action Q values of `online`, backward pass, in-place Adam on
    /// `online`/`m`/`v`. `t` is the 1-based Adam timestep. Returns the
    /// loss. Allocation-free.
    pub fn step(
        &mut self,
        online: &mut QNetParams,
        target: &QNetParams,
        m: &mut QNetParams,
        v: &mut QNetParams,
        t: u64,
        batch: &SampleBatch,
    ) -> f32 {
        let b = self.batch;
        assert_eq!(batch.batch, b, "SampleBatch size != scratch size");
        debug_assert!(t >= 1, "Adam timestep is 1-based");
        debug_assert_eq!(online.dims, self.dims);
        debug_assert_eq!(target.dims, self.dims);
        let (d, h1, h2, a) = self.dims;

        // Target-net forward on s′ (no gradient).
        gemm_bias(&batch.next_states, &target.w1, &target.b1, &mut self.th1, b, d, h1);
        relu(&mut self.th1);
        gemm_bias(&self.th1, &target.w2, &target.b2, &mut self.th2, b, h1, h2);
        relu(&mut self.th2);
        gemm_bias(&self.th2, &target.w3, &target.b3, &mut self.tq, b, h2, a);

        // TD targets: r + γ·(1−done)·max_a′ Q′(s′) (stop-gradient side).
        for i in 0..b {
            let row = &self.tq[i * a..(i + 1) * a];
            let mut qmax = row[0];
            for &qv in &row[1..] {
                if qv > qmax {
                    qmax = qv;
                }
            }
            self.targets[i] = batch.rewards[i] + GAMMA * (1.0 - batch.dones[i]) * qmax;
        }

        // Online forward on s, keeping pre-activations for the backward.
        gemm_bias(&batch.states, &online.w1, &online.b1, &mut self.z1, b, d, h1);
        self.a1.copy_from_slice(&self.z1);
        relu(&mut self.a1);
        gemm_bias(&self.a1, &online.w2, &online.b2, &mut self.z2, b, h1, h2);
        self.a2.copy_from_slice(&self.z2);
        relu(&mut self.a2);
        gemm_bias(&self.a2, &online.w3, &online.b3, &mut self.q, b, h2, a);

        // Mean Huber loss on the chosen actions; dL/dq is nonzero only at
        // the selected entries: clamp(err, ±δ)/B (exact for B a power of
        // two; the clamp is the Huber derivative on both branches).
        self.dq.fill(0.0);
        let mut loss_sum = 0.0f32;
        for i in 0..b {
            let act = batch.actions[i] as usize;
            debug_assert!(act < a, "action index out of range");
            let err = self.q[i * a + act] - self.targets[i];
            let abs = err.abs();
            loss_sum += if abs <= HUBER_DELTA {
                0.5 * err * err
            } else {
                HUBER_DELTA * (abs - 0.5 * HUBER_DELTA)
            };
            self.dq[i * a + act] = err.clamp(-HUBER_DELTA, HUBER_DELTA) / b as f32;
        }
        let loss = loss_sum / b as f32;

        // Backward: layer 3 → 1. ReLU gradient is 1 for z>0, 0 for z<0,
        // and 0.5 at z==0 exactly (JAX balanced `maximum` tie).
        grad_weights(&self.a2, &self.dq, &mut self.g.w3, b, h2, a);
        grad_bias(&self.dq, &mut self.g.b3, b, a);
        gemm_wt(&self.dq, &online.w3, &mut self.dh2, b, h2, a);
        relu_backward(&mut self.dh2, &self.z2);

        grad_weights(&self.a1, &self.dh2, &mut self.g.w2, b, h1, h2);
        grad_bias(&self.dh2, &mut self.g.b2, b, h2);
        gemm_wt(&self.dh2, &online.w2, &mut self.dh1, b, h1, h2);
        relu_backward(&mut self.dh1, &self.z1);

        grad_weights(&batch.states, &self.dh1, &mut self.g.w1, b, d, h1);
        grad_bias(&self.dh1, &mut self.g.b1, b, h1);

        // In-place Adam with bias correction (t cast to f32 like the
        // jaxpr's step counter).
        let tf = t as f32;
        let bc1 = 1.0 - ADAM_B1.powf(tf);
        let bc2 = 1.0 - ADAM_B2.powf(tf);
        adam_update(&mut online.w1, &mut m.w1, &mut v.w1, &self.g.w1, bc1, bc2);
        adam_update(&mut online.b1, &mut m.b1, &mut v.b1, &self.g.b1, bc1, bc2);
        adam_update(&mut online.w2, &mut m.w2, &mut v.w2, &self.g.w2, bc1, bc2);
        adam_update(&mut online.b2, &mut m.b2, &mut v.b2, &self.g.b2, bc1, bc2);
        adam_update(&mut online.w3, &mut m.w3, &mut v.w3, &self.g.w3, bc1, bc2);
        adam_update(&mut online.b3, &mut m.b3, &mut v.b3, &self.g.b3, bc1, bc2);

        loss
    }
}

/// dh ⊙= relu′(z): 1 for z>0, 0 for z<0, 0.5 at the z==0 tie.
#[inline]
fn relu_backward(dh: &mut [f32], z: &[f32]) {
    debug_assert_eq!(dh.len(), z.len());
    for (g, &zi) in dh.iter_mut().zip(z.iter()) {
        if zi < 0.0 {
            *g = 0.0;
        } else if zi == 0.0 {
            *g *= 0.5;
        }
    }
}

/// p −= (lr·m̂)/(√v̂ + ε), updating the moments in place. Operation order
/// and associativity mirror the compiled jaxpr exactly.
#[inline]
fn adam_update(p: &mut [f32], m: &mut [f32], v: &mut [f32], g: &[f32], bc1: f32, bc2: f32) {
    debug_assert!(p.len() == m.len() && m.len() == v.len() && v.len() == g.len());
    for (((pi, mi), vi), &gi) in p.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(g.iter()) {
        *mi = ADAM_B1 * *mi + ONE_MINUS_B1 * gi;
        *vi = ADAM_B2 * *vi + ONE_MINUS_B2 * gi * gi;
        let m_hat = *mi / bc1;
        let v_hat = *vi / bc2;
        *pi -= (LR * m_hat) / (v_hat.sqrt() + ADAM_EPS);
    }
}

/// [`TrainBackend`] over [`NativeTrainStep`]: owns the online/target
/// parameters and the Adam moments, double-buffered so every step mutates
/// the same four tensors in place.
#[derive(Debug, Clone)]
pub struct NativeBackend {
    kernel: NativeTrainStep,
    online: QNetParams,
    target: QNetParams,
    m: QNetParams,
    v: QNetParams,
}

impl NativeBackend {
    /// Start from `init` (online and target both set to it, zero moments).
    pub fn new(init: QNetParams, batch: usize) -> Self {
        let dims = init.dims;
        NativeBackend {
            kernel: NativeTrainStep::new(dims, batch),
            target: init.clone(),
            m: QNetParams::zeros(dims),
            v: QNetParams::zeros(dims),
            online: init,
        }
    }

    /// Adam moments (cross-backend agreement tests).
    pub fn moments(&self) -> (&QNetParams, &QNetParams) {
        (&self.m, &self.v)
    }
}

impl TrainBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn step(&mut self, t: u64, batch: &SampleBatch) -> anyhow::Result<f32> {
        Ok(self.kernel.step(&mut self.online, &self.target, &mut self.m, &mut self.v, t, batch))
    }

    fn sync_target(&mut self) {
        self.target.copy_from(&self.online);
    }

    fn snapshot(&self) -> Arc<QNetParams> {
        Arc::new(self.online.clone())
    }

    fn params(&self) -> &QNetParams {
        &self.online
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::encoder::STATE_DIM;
    use crate::util::rng::Rng;

    const DIMS: (usize, usize, usize, usize) = (STATE_DIM, 16, 16, 5);

    fn synthetic_batch(rng: &mut Rng, b: usize) -> SampleBatch {
        let mut sb = SampleBatch::new(b);
        for x in sb.states.iter_mut().chain(sb.next_states.iter_mut()) {
            *x = rng.normal(0.0, 1.0) as f32;
        }
        for a in sb.actions.iter_mut() {
            *a = rng.index(DIMS.3) as i32;
        }
        for r in sb.rewards.iter_mut() {
            *r = rng.normal(-1.0, 2.0) as f32;
        }
        for (i, d) in sb.dones.iter_mut().enumerate() {
            *d = if i % 7 == 0 { 1.0 } else { 0.0 };
        }
        sb
    }

    /// f64 reference implementation of the entire train step.
    struct RefStep {
        p: Vec<Vec<f64>>, // w1,b1,w2,b2,w3,b3
        m: Vec<Vec<f64>>,
        v: Vec<Vec<f64>>,
        tp: Vec<Vec<f64>>,
    }

    fn dense(x: &[f64], w: &[f64], b: &[f64], d_in: usize, d_out: usize, rows: usize) -> Vec<f64> {
        let mut y = vec![0.0; rows * d_out];
        for r in 0..rows {
            for j in 0..d_out {
                let mut acc = b[j];
                for i in 0..d_in {
                    acc += x[r * d_in + i] * w[i * d_out + j];
                }
                y[r * d_out + j] = acc;
            }
        }
        y
    }

    impl RefStep {
        fn from(p: &QNetParams) -> Self {
            let to64 = |v: &Vec<f32>| v.iter().map(|&x| x as f64).collect::<Vec<f64>>();
            let ps: Vec<Vec<f64>> = p.tensors().iter().map(|(_, _, d)| to64(d)).collect();
            let zs: Vec<Vec<f64>> = ps.iter().map(|t| vec![0.0; t.len()]).collect();
            RefStep { tp: ps.clone(), p: ps, v: zs.clone(), m: zs }
        }

        /// Returns pre-activations (z1, z2) and the final q; activations
        /// are recomputed by the caller as max(z, 0).
        fn forward(p: &[Vec<f64>], x: &[f64], rows: usize) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
            let (d, h1, h2, a) = DIMS;
            let z1 = dense(x, &p[0], &p[1], d, h1, rows);
            let a1: Vec<f64> = z1.iter().map(|&v| v.max(0.0)).collect();
            let z2 = dense(&a1, &p[2], &p[3], h1, h2, rows);
            let a2: Vec<f64> = z2.iter().map(|&v| v.max(0.0)).collect();
            let q = dense(&a2, &p[4], &p[5], h2, a, rows);
            (z1, z2, q)
        }

        fn step(&mut self, t: u64, sb: &SampleBatch) -> f64 {
            let (d, h1, h2, a) = DIMS;
            let b = sb.batch;
            let s: Vec<f64> = sb.states.iter().map(|&x| x as f64).collect();
            let ns: Vec<f64> = sb.next_states.iter().map(|&x| x as f64).collect();

            let (_, _, tq) = Self::forward(&self.tp, &ns, b);
            let mut targets = vec![0.0; b];
            for i in 0..b {
                let row = &tq[i * a..(i + 1) * a];
                let qmax = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                targets[i] =
                    sb.rewards[i] as f64 + GAMMA as f64 * (1.0 - sb.dones[i] as f64) * qmax;
            }

            let (z1, z2, q) = Self::forward(&self.p, &s, b);
            let a1: Vec<f64> = z1.iter().map(|&z| z.max(0.0)).collect();
            let a2: Vec<f64> = z2.iter().map(|&z| z.max(0.0)).collect();

            let mut dq = vec![0.0; b * a];
            let mut loss = 0.0;
            let delta = HUBER_DELTA as f64;
            for i in 0..b {
                let act = sb.actions[i] as usize;
                let err = q[i * a + act] - targets[i];
                loss += if err.abs() <= delta {
                    0.5 * err * err
                } else {
                    delta * (err.abs() - 0.5 * delta)
                };
                dq[i * a + act] = err.clamp(-delta, delta) / b as f64;
            }
            loss /= b as f64;

            let colsum = |dy: &[f64], n: usize| {
                let mut g = vec![0.0; n];
                for r in 0..b {
                    for j in 0..n {
                        g[j] += dy[r * n + j];
                    }
                }
                g
            };
            let matt = |x: &[f64], dy: &[f64], di: usize, dn: usize| {
                let mut g = vec![0.0; di * dn];
                for r in 0..b {
                    for i in 0..di {
                        for j in 0..dn {
                            g[i * dn + j] += x[r * di + i] * dy[r * dn + j];
                        }
                    }
                }
                g
            };
            let backprop = |dy: &[f64], w: &[f64], di: usize, dn: usize| {
                let mut dx = vec![0.0; b * di];
                for r in 0..b {
                    for i in 0..di {
                        for j in 0..dn {
                            dx[r * di + i] += dy[r * dn + j] * w[i * dn + j];
                        }
                    }
                }
                dx
            };
            let relu_bw = |dh: &mut Vec<f64>, z: &[f64]| {
                for (g, &zi) in dh.iter_mut().zip(z.iter()) {
                    if zi < 0.0 {
                        *g = 0.0;
                    } else if zi == 0.0 {
                        *g *= 0.5;
                    }
                }
            };

            let gw3 = matt(&a2, &dq, h2, a);
            let gb3 = colsum(&dq, a);
            let mut dh2 = backprop(&dq, &self.p[4], h2, a);
            relu_bw(&mut dh2, &z2);
            let gw2 = matt(&a1, &dh2, h1, h2);
            let gb2 = colsum(&dh2, h2);
            let mut dh1 = backprop(&dh2, &self.p[2], h1, h2);
            relu_bw(&mut dh1, &z1);
            let gw1 = matt(&s, &dh1, d, h1);
            let gb1 = colsum(&dh1, h1);

            let grads = [gw1, gb1, gw2, gb2, gw3, gb3];
            let bc1 = 1.0 - (ADAM_B1 as f64).powi(t as i32);
            let bc2 = 1.0 - (ADAM_B2 as f64).powi(t as i32);
            for (k, g) in grads.iter().enumerate() {
                for i in 0..g.len() {
                    self.m[k][i] = ADAM_B1 as f64 * self.m[k][i] + (1.0 - ADAM_B1 as f64) * g[i];
                    self.v[k][i] =
                        ADAM_B2 as f64 * self.v[k][i] + (1.0 - ADAM_B2 as f64) * g[i] * g[i];
                    let m_hat = self.m[k][i] / bc1;
                    let v_hat = self.v[k][i] / bc2;
                    self.p[k][i] -= LR as f64 * m_hat / (v_hat.sqrt() + ADAM_EPS as f64);
                }
            }
            loss
        }
    }

    #[test]
    fn matches_f64_reference_over_steps() {
        let init = QNetParams::he_uniform(DIMS, 5);
        let mut backend = NativeBackend::new(init.clone(), 32);
        let mut reference = RefStep::from(&init);
        let mut rng = Rng::new(17);
        let mut worst = 0.0f64;
        for t in 1..=20u64 {
            let sb = synthetic_batch(&mut rng, 32);
            let loss = backend.step(t, &sb).unwrap();
            let ref_loss = reference.step(t, &sb);
            assert!(
                (loss as f64 - ref_loss).abs() < 1e-4,
                "loss diverged at t={t}: {loss} vs {ref_loss}"
            );
            let got = backend.params();
            for (k, (_, _, data)) in got.tensors().iter().enumerate() {
                for (i, &gv) in data.iter().enumerate() {
                    worst = worst.max((gv as f64 - reference.p[k][i]).abs());
                }
            }
        }
        assert!(worst < 1e-4, "param drift vs f64 reference: {worst}");
    }

    #[test]
    fn bit_identical_across_reruns() {
        let run = || {
            let mut backend = NativeBackend::new(QNetParams::he_uniform(DIMS, 5), 32);
            let mut rng = Rng::new(23);
            for t in 1..=50u64 {
                let sb = synthetic_batch(&mut rng, 32);
                backend.step(t, &sb).unwrap();
                if t % 10 == 0 {
                    backend.sync_target();
                }
            }
            backend.params().clone()
        };
        let a = run();
        let b = run();
        assert_eq!(a.max_abs_diff(&b), 0.0, "native training must be bit-identical");
        let bits_equal = a
            .tensors()
            .iter()
            .zip(b.tensors().iter())
            .all(|((_, _, xa), (_, _, xb))| {
                xa.iter().zip(xb.iter()).all(|(p, q)| p.to_bits() == q.to_bits())
            });
        assert!(bits_equal, "bit patterns diverged across reruns");
    }

    #[test]
    fn sync_target_copies_online() {
        let mut backend = NativeBackend::new(QNetParams::he_uniform(DIMS, 8), 8);
        let mut rng = Rng::new(3);
        let sb = synthetic_batch(&mut rng, 8);
        backend.step(1, &sb).unwrap();
        // Target still holds the init → next step differs from a synced run.
        backend.sync_target();
        let snap = backend.snapshot();
        assert_eq!(backend.params().max_abs_diff(&snap), 0.0);
    }

    #[test]
    fn one_minus_beta_constants_match_f64_folding() {
        // XLA folds `1.0 - 0.9` in f64 before casting to f32; the naive
        // f32 subtraction lands on different bits.
        assert_eq!(ONE_MINUS_B1.to_bits(), 0.1f32.to_bits());
        assert_ne!((1.0f32 - ADAM_B1).to_bits(), ONE_MINUS_B1.to_bits());
        assert_eq!(ONE_MINUS_B2.to_bits(), 0.001f32.to_bits());
    }
}
