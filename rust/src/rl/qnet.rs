//! Q-network parameter container shared by the native forward pass, the
//! PJRT executables, and weight serialization.
//!
//! Layout mirrors `python/compile/model.py` (`PARAM_KEYS` order, row-major
//! f32); the two sides must change in lockstep.

/// Parameter-tensor order, identical to model.py's `PARAM_KEYS`.
pub const PARAM_KEYS: [&str; 6] = ["w1", "b1", "w2", "b2", "w3", "b3"];

/// The 3-layer MLP parameters. `dims = (state_dim, h1, h2, n_actions)`.
#[derive(Debug, Clone, PartialEq)]
pub struct QNetParams {
    pub dims: (usize, usize, usize, usize),
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub w3: Vec<f32>,
    pub b3: Vec<f32>,
}

impl QNetParams {
    pub fn state_dim(&self) -> usize {
        self.dims.0
    }
    pub fn hidden1(&self) -> usize {
        self.dims.1
    }
    pub fn hidden2(&self) -> usize {
        self.dims.2
    }
    pub fn n_actions(&self) -> usize {
        self.dims.3
    }

    /// All-zero parameters with the given dims (Adam moment init).
    pub fn zeros(dims: (usize, usize, usize, usize)) -> Self {
        let (d, h1, h2, a) = dims;
        QNetParams {
            dims,
            w1: vec![0.0; d * h1],
            b1: vec![0.0; h1],
            w2: vec![0.0; h1 * h2],
            b2: vec![0.0; h2],
            w3: vec![0.0; h2 * a],
            b3: vec![0.0; a],
        }
    }

    /// He-uniform initial weights (zero biases), deterministic in `seed`.
    /// Rust-side stand-in for the compiled artifact's initial params so the
    /// native backend can train without any PJRT assets on disk.
    pub fn he_uniform(dims: (usize, usize, usize, usize), seed: u64) -> Self {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let mut p = Self::zeros(dims);
        let (d, h1, h2, _) = dims;
        for (w, fan_in) in [(&mut p.w1, d), (&mut p.w2, h1), (&mut p.w3, h2)] {
            let limit = (6.0 / fan_in as f64).sqrt();
            for v in w.iter_mut() {
                *v = rng.range(-limit, limit) as f32;
            }
        }
        p
    }

    /// Copy `other`'s values into this instance's existing buffers — no
    /// heap allocation (unlike `clone`). Panics if dims differ.
    pub fn copy_from(&mut self, other: &QNetParams) {
        assert_eq!(self.dims, other.dims, "copy_from dims mismatch");
        self.w1.copy_from_slice(&other.w1);
        self.b1.copy_from_slice(&other.b1);
        self.w2.copy_from_slice(&other.w2);
        self.b2.copy_from_slice(&other.b2);
        self.w3.copy_from_slice(&other.w3);
        self.b3.copy_from_slice(&other.b3);
    }

    /// Tensors in PARAM_KEYS order with their shapes.
    pub fn tensors(&self) -> [(&'static str, Vec<usize>, &Vec<f32>); 6] {
        let (d, h1, h2, a) = self.dims;
        [
            ("w1", vec![d, h1], &self.w1),
            ("b1", vec![h1], &self.b1),
            ("w2", vec![h1, h2], &self.w2),
            ("b2", vec![h2], &self.b2),
            ("w3", vec![h2, a], &self.w3),
            ("b3", vec![a], &self.b3),
        ]
    }

    /// Mutable tensor data in PARAM_KEYS order.
    pub fn tensors_mut(&mut self) -> [&mut Vec<f32>; 6] {
        [
            &mut self.w1,
            &mut self.b1,
            &mut self.w2,
            &mut self.b2,
            &mut self.w3,
            &mut self.b3,
        ]
    }

    /// Build from named tensors (weight-file or PJRT output order agnostic).
    pub fn from_named(named: &[(String, Vec<usize>, Vec<f32>)]) -> anyhow::Result<Self> {
        let find = |key: &str| -> anyhow::Result<(&Vec<usize>, &Vec<f32>)> {
            named
                .iter()
                .find(|(n, _, _)| n == key)
                .map(|(_, s, d)| (s, d))
                .ok_or_else(|| anyhow::anyhow!("missing tensor '{key}'"))
        };
        let (s1, w1) = find("w1")?;
        let (_, b1) = find("b1")?;
        let (s2, w2) = find("w2")?;
        let (_, b2) = find("b2")?;
        let (s3, w3) = find("w3")?;
        let (sb3, b3) = find("b3")?;
        anyhow::ensure!(s1.len() == 2 && s2.len() == 2 && s3.len() == 2, "weights must be 2-D");
        let dims = (s1[0], s1[1], s2[1], s3[1]);
        anyhow::ensure!(s2[0] == dims.1, "w2 input dim mismatch");
        anyhow::ensure!(s3[0] == dims.2, "w3 input dim mismatch");
        anyhow::ensure!(sb3 == &vec![dims.3], "b3 shape mismatch");
        let p = QNetParams {
            dims,
            w1: w1.clone(),
            b1: b1.clone(),
            w2: w2.clone(),
            b2: b2.clone(),
            w3: w3.clone(),
            b3: b3.clone(),
        };
        p.validate()?;
        Ok(p)
    }

    /// Check internal consistency of vector lengths vs dims.
    pub fn validate(&self) -> anyhow::Result<()> {
        let (d, h1, h2, a) = self.dims;
        anyhow::ensure!(self.w1.len() == d * h1, "w1 size");
        anyhow::ensure!(self.b1.len() == h1, "b1 size");
        anyhow::ensure!(self.w2.len() == h1 * h2, "w2 size");
        anyhow::ensure!(self.b2.len() == h2, "b2 size");
        anyhow::ensure!(self.w3.len() == h2 * a, "w3 size");
        anyhow::ensure!(self.b3.len() == a, "b3 size");
        Ok(())
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.w1.len()
            + self.b1.len()
            + self.w2.len()
            + self.b2.len()
            + self.w3.len()
            + self.b3.len()
    }

    /// Max |a - b| across all tensors (convergence / agreement checks).
    /// Returns `f32::INFINITY` when the architectures differ — a silent
    /// element-wise zip over mismatched dims would truncate and could
    /// report two different networks as "equal".
    pub fn max_abs_diff(&self, other: &QNetParams) -> f32 {
        if self.dims != other.dims {
            return f32::INFINITY;
        }
        let mut m = 0.0f32;
        for (a, b) in self
            .tensors()
            .iter()
            .zip(other.tensors().iter())
            .flat_map(|((_, _, xa), (_, _, xb))| xa.iter().zip(xb.iter()))
        {
            m = m.max((a - b).abs());
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shapes() {
        let p = QNetParams::zeros((10, 64, 64, 5));
        p.validate().unwrap();
        assert_eq!(p.n_params(), 10 * 64 + 64 + 64 * 64 + 64 + 64 * 5 + 5);
    }

    #[test]
    fn from_named_any_order() {
        let p = QNetParams::zeros((3, 4, 4, 2));
        let mut named: Vec<(String, Vec<usize>, Vec<f32>)> = p
            .tensors()
            .iter()
            .map(|(n, s, d)| (n.to_string(), s.clone(), (*d).clone()))
            .collect();
        named.reverse();
        let q = QNetParams::from_named(&named).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn from_named_missing_tensor() {
        let p = QNetParams::zeros((3, 4, 4, 2));
        let named: Vec<(String, Vec<usize>, Vec<f32>)> = p
            .tensors()
            .iter()
            .take(5)
            .map(|(n, s, d)| (n.to_string(), s.clone(), (*d).clone()))
            .collect();
        assert!(QNetParams::from_named(&named).is_err());
    }

    #[test]
    fn max_abs_diff() {
        let a = QNetParams::zeros((2, 2, 2, 2));
        let mut b = a.clone();
        b.w2[3] = -0.25;
        assert_eq!(a.max_abs_diff(&b), 0.25);
        assert_eq!(a.max_abs_diff(&a), 0.0);
    }

    #[test]
    fn max_abs_diff_mismatched_dims_is_infinite() {
        // A silent zip over different architectures would truncate to the
        // shorter tensors and could report 0.0 for unequal networks.
        let a = QNetParams::zeros((2, 2, 2, 2));
        let b = QNetParams::zeros((2, 4, 4, 2));
        assert_eq!(a.max_abs_diff(&b), f32::INFINITY);
        assert_eq!(b.max_abs_diff(&a), f32::INFINITY);
    }

    #[test]
    fn he_uniform_deterministic_and_bounded() {
        let a = QNetParams::he_uniform((10, 64, 64, 5), 7);
        let b = QNetParams::he_uniform((10, 64, 64, 5), 7);
        let c = QNetParams::he_uniform((10, 64, 64, 5), 8);
        assert_eq!(a.max_abs_diff(&b), 0.0, "same seed must be identical");
        assert!(a.max_abs_diff(&c) > 0.0, "different seed must differ");
        assert!(a.b1.iter().all(|&v| v == 0.0), "biases start at zero");
        let limit = (6.0f64 / 10.0).sqrt() as f32;
        assert!(a.w1.iter().all(|&v| v.abs() <= limit));
        assert!(a.w1.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn copy_from_overwrites_in_place() {
        let src = QNetParams::he_uniform((3, 4, 4, 2), 11);
        let mut dst = QNetParams::zeros((3, 4, 4, 2));
        dst.copy_from(&src);
        assert_eq!(dst.max_abs_diff(&src), 0.0);
    }
}
