//! Experience replay buffer (paper §III-C / §IV-A4: capacity 10,000,
//! uniform sampling, batch 64).
//!
//! Stores transitions in fixed arrays and fills caller-provided flat
//! buffers for the PJRT train step — no allocation per sample.

use crate::rl::encoder::STATE_DIM;
use crate::util::rng::Rng;

/// One (s, a, r, s′, done) transition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transition {
    pub state: [f32; STATE_DIM],
    pub action: u8,
    pub reward: f32,
    pub next_state: [f32; STATE_DIM],
    pub done: bool,
}

/// Preallocated flat minibatch buffers shared by both train backends.
///
/// Owning the five arrays as one struct lets the trainer sample once per
/// gradient step with zero allocation and hand the same view to either the
/// PJRT executable or the native train step (the cross-backend property
/// test feeds both from a single `SampleBatch`).
#[derive(Debug, Clone)]
pub struct SampleBatch {
    pub batch: usize,
    /// `[batch * STATE_DIM]` row-major.
    pub states: Vec<f32>,
    /// Action indices, i32 to match the executable's input dtype.
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    /// `[batch * STATE_DIM]` row-major.
    pub next_states: Vec<f32>,
    /// 1.0 terminal / 0.0 otherwise.
    pub dones: Vec<f32>,
}

impl SampleBatch {
    pub fn new(batch: usize) -> Self {
        assert!(batch > 0);
        SampleBatch {
            batch,
            states: vec![0.0; batch * STATE_DIM],
            actions: vec![0; batch],
            rewards: vec![0.0; batch],
            next_states: vec![0.0; batch * STATE_DIM],
            dones: vec![0.0; batch],
        }
    }
}

/// Ring-buffer replay memory with uniform sampling.
#[derive(Debug, Clone)]
pub struct ReplayBuffer {
    buf: Vec<Transition>,
    capacity: usize,
    head: usize,
    /// Total pushes ever (monotone; len() = min(pushes, capacity)).
    pushes: u64,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ReplayBuffer { buf: Vec::with_capacity(capacity), capacity, head: 0, pushes: 0 }
    }

    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
        self.pushes += 1;
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn pushes(&self) -> u64 {
        self.pushes
    }

    /// Sample `batch` transitions uniformly (with replacement) into flat
    /// arrays shaped for the `dqn_train_step` executable inputs.
    ///
    /// `states`/`next_states`: `[batch * STATE_DIM]` row-major;
    /// `actions`: i32 indices; `rewards`, `dones`: f32.
    pub fn sample_into(
        &self,
        rng: &mut Rng,
        batch: usize,
        states: &mut [f32],
        actions: &mut [i32],
        rewards: &mut [f32],
        next_states: &mut [f32],
        dones: &mut [f32],
    ) {
        assert!(!self.buf.is_empty(), "sampling from empty replay buffer");
        assert_eq!(states.len(), batch * STATE_DIM);
        assert_eq!(next_states.len(), batch * STATE_DIM);
        assert_eq!(actions.len(), batch);
        for b in 0..batch {
            let t = &self.buf[rng.index(self.buf.len())];
            states[b * STATE_DIM..(b + 1) * STATE_DIM].copy_from_slice(&t.state);
            next_states[b * STATE_DIM..(b + 1) * STATE_DIM]
                .copy_from_slice(&t.next_state);
            actions[b] = t.action as i32;
            rewards[b] = t.reward;
            dones[b] = if t.done { 1.0 } else { 0.0 };
        }
    }

    /// [`sample_into`](Self::sample_into) with a [`SampleBatch`]'s own
    /// buffers — the per-gradient-step sampling path.
    pub fn sample_batch(&self, rng: &mut Rng, out: &mut SampleBatch) {
        let SampleBatch { batch, states, actions, rewards, next_states, dones } = out;
        self.sample_into(rng, *batch, states, actions, rewards, next_states, dones);
    }

    /// Iterate stored transitions (diagnostics / tests).
    pub fn iter(&self) -> impl Iterator<Item = &Transition> {
        self.buf.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: f32) -> Transition {
        Transition {
            state: [v; STATE_DIM],
            action: (v as usize % 5) as u8,
            reward: -v,
            next_state: [v + 1.0; STATE_DIM],
            done: false,
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(3);
        for i in 0..5 {
            rb.push(t(i as f32));
        }
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.pushes(), 5);
        let stored: Vec<f32> = rb.iter().map(|x| x.state[0]).collect();
        // 0 and 1 evicted.
        assert!(stored.contains(&2.0) && stored.contains(&3.0) && stored.contains(&4.0));
    }

    #[test]
    fn sample_fills_flat_arrays() {
        let mut rb = ReplayBuffer::new(10);
        for i in 0..10 {
            rb.push(t(i as f32));
        }
        let batch = 4;
        let mut s = vec![0.0; batch * STATE_DIM];
        let mut a = vec![0i32; batch];
        let mut r = vec![0.0f32; batch];
        let mut ns = vec![0.0; batch * STATE_DIM];
        let mut d = vec![0.0f32; batch];
        let mut rng = Rng::new(1);
        rb.sample_into(&mut rng, batch, &mut s, &mut a, &mut r, &mut ns, &mut d);
        for b in 0..batch {
            let v = s[b * STATE_DIM];
            assert!(s[b * STATE_DIM..(b + 1) * STATE_DIM].iter().all(|&x| x == v));
            assert_eq!(r[b], -v);
            assert_eq!(ns[b * STATE_DIM], v + 1.0);
            assert_eq!(a[b], (v as usize % 5) as i32);
        }
    }

    #[test]
    fn done_flag_converts_to_float() {
        let mut rb = ReplayBuffer::new(2);
        let mut tr = t(1.0);
        tr.done = true;
        rb.push(tr);
        let mut s = vec![0.0; STATE_DIM];
        let mut a = vec![0i32; 1];
        let mut r = vec![0.0f32; 1];
        let mut ns = vec![0.0; STATE_DIM];
        let mut d = vec![0.0f32; 1];
        let mut rng = Rng::new(2);
        rb.sample_into(&mut rng, 1, &mut s, &mut a, &mut r, &mut ns, &mut d);
        assert_eq!(d[0], 1.0);
    }

    #[test]
    fn sample_batch_matches_sample_into() {
        let mut rb = ReplayBuffer::new(16);
        for i in 0..16 {
            rb.push(t(i as f32));
        }
        let batch = 8;
        let mut sb = SampleBatch::new(batch);
        let mut rng_a = Rng::new(99);
        rb.sample_batch(&mut rng_a, &mut sb);

        let mut s = vec![0.0; batch * STATE_DIM];
        let mut a = vec![0i32; batch];
        let mut r = vec![0.0f32; batch];
        let mut ns = vec![0.0; batch * STATE_DIM];
        let mut d = vec![0.0f32; batch];
        let mut rng_b = Rng::new(99);
        rb.sample_into(&mut rng_b, batch, &mut s, &mut a, &mut r, &mut ns, &mut d);

        assert_eq!(sb.states, s);
        assert_eq!(sb.actions, a);
        assert_eq!(sb.rewards, r);
        assert_eq!(sb.next_states, ns);
        assert_eq!(sb.dones, d);
    }

    #[test]
    #[should_panic(expected = "empty replay buffer")]
    fn empty_sample_panics() {
        let rb = ReplayBuffer::new(2);
        let mut rng = Rng::new(1);
        let mut s = vec![0.0; STATE_DIM];
        let mut a = vec![0i32; 1];
        let mut r = vec![0.0f32; 1];
        let mut ns = vec![0.0; STATE_DIM];
        let mut d = vec![0.0f32; 1];
        rb.sample_into(&mut rng, 1, &mut s, &mut a, &mut r, &mut ns, &mut d);
    }
}
