//! Rust-side DQN training loop, generic over the gradient-step backend.
//!
//! Python is compile-time only: the entire training loop — episodes over
//! the training trace, ε decay, replay sampling, target-network syncs —
//! runs here. Each gradient step goes through a
//! [`TrainBackend`]: either the AOT PJRT `dqn_train_step` executable
//! ([`crate::runtime::backend::PjrtBackend`]) or the pure-Rust batched
//! step ([`crate::rl::native_train::NativeBackend`]), selected by
//! [`TrainerConfig::backend`] (CLI: `--backend native|pjrt`).
//!
//! Schedule (paper §IV-A4 scaled to this testbed): per episode the agent
//! replays the training trace slice with ε-greedy exploration, harvested
//! transitions land in the 10,000-slot replay buffer, then
//! `steps_per_episode` Adam steps are applied (batch 64, lr 1e-3, γ 0.99).
//! The target network syncs every `target_sync_steps` gradient steps, ε
//! decays ×0.95 per episode to 0.05. λ_carbon is sampled per episode so the
//! network learns the preference-conditioned policy (§III-C).

use std::time::Instant;

use crate::carbon::intensity::CarbonTrace;
use crate::energy::model::EnergyModel;
use crate::policy::native_mlp::NativeMlp;
use crate::rl::agent::EpsilonGreedyAgent;
use crate::rl::backend::{BackendKind, TrainBackend};
use crate::rl::encoder::STATE_DIM;
use crate::rl::native_train::NativeBackend;
use crate::rl::qnet::QNetParams;
use crate::rl::replay::{ReplayBuffer, SampleBatch};
use crate::runtime::backend::PjrtBackend;
use crate::runtime::{ArtifactSet, PjrtRuntime, TrainStep};
use crate::simulator::engine::SimConfig;
use crate::simulator::sharded::ShardedSimulator;
use crate::trace::model::Trace;
use crate::util::rng::Rng;

/// Training hyper-parameters.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub episodes: usize,
    pub steps_per_episode: usize,
    pub replay_capacity: usize,
    pub batch: usize,
    pub epsilon_start: f64,
    pub epsilon_min: f64,
    pub epsilon_decay: f64,
    pub target_sync_steps: usize,
    /// Fixed λ_carbon, or None to sample per episode from {0.1 … 0.9}.
    pub lambda_carbon: Option<f64>,
    pub seed: u64,
    /// Which gradient-step engine to drive (see [`BackendKind`]).
    pub backend: BackendKind,
    /// Print per-episode progress lines.
    pub verbose: bool,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            episodes: 30,
            steps_per_episode: 800,
            replay_capacity: 10_000,
            batch: 64,
            epsilon_start: 1.0,
            epsilon_min: 0.05,
            epsilon_decay: 0.95,
            target_sync_steps: 500,
            lambda_carbon: None,
            seed: 17,
            backend: BackendKind::Pjrt,
            verbose: true,
        }
    }
}

impl TrainerConfig {
    /// Tiny schedule for tests.
    pub fn smoke() -> Self {
        TrainerConfig {
            episodes: 2,
            steps_per_episode: 10,
            verbose: false,
            ..TrainerConfig::default()
        }
    }

    /// Reject configurations the loop cannot run. In particular
    /// `target_sync_steps == 0` used to reach a `% 0` panic deep in the
    /// gradient loop; fail here with a real error instead.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.episodes > 0, "episodes must be ≥ 1");
        anyhow::ensure!(self.batch > 0, "batch must be ≥ 1");
        anyhow::ensure!(
            self.replay_capacity >= self.batch,
            "replay_capacity {} must be ≥ batch {}",
            self.replay_capacity,
            self.batch
        );
        anyhow::ensure!(
            self.target_sync_steps > 0,
            "target_sync_steps must be ≥ 1 (a zero cadence would never sync and \
             divides by zero)"
        );
        anyhow::ensure!(
            self.epsilon_decay > 0.0 && self.epsilon_decay <= 1.0,
            "epsilon_decay must be in (0, 1], got {}",
            self.epsilon_decay
        );
        Ok(())
    }
}

/// Per-episode training statistics.
#[derive(Debug, Clone)]
pub struct EpisodeStats {
    pub episode: usize,
    pub epsilon: f64,
    pub lambda: f64,
    pub transitions: usize,
    pub mean_loss: f32,
    pub episode_reward: f64,
    /// Gradient-step throughput over this episode's training phase
    /// (steps/sec; 0.0 when the episode ran no gradient steps).
    pub grad_steps_per_s: f64,
}

/// Final training report.
pub struct TrainReport {
    pub params: QNetParams,
    pub episodes: Vec<EpisodeStats>,
    pub total_steps: u64,
    /// Name of the backend that produced the weights.
    pub backend: &'static str,
}

/// Default network architecture when no artifact manifest dictates one
/// (native-backend training from scratch).
pub fn default_dims() -> (usize, usize, usize, usize) {
    (STATE_DIM, 64, 64, crate::KEEP_ALIVE_ACTIONS.len())
}

/// Train a DQN on `trace` using the backend selected by `cfg.backend`,
/// starting from the artifact set's initial parameters.
pub fn train(
    artifacts: &ArtifactSet,
    runtime: &PjrtRuntime,
    trace: &Trace,
    ci: &CarbonTrace,
    energy: &EnergyModel,
    cfg: &TrainerConfig,
) -> anyhow::Result<TrainReport> {
    cfg.validate()?;
    let init = artifacts.init_params()?;
    match cfg.backend {
        BackendKind::Pjrt => {
            let dims = artifacts.manifest.dims();
            anyhow::ensure!(
                cfg.batch == artifacts.manifest.train_batch,
                "batch mismatch with artifact"
            );
            let exe = runtime.load_hlo_text(artifacts.train_step_path().to_str().unwrap())?;
            let mut backend = PjrtBackend::new(TrainStep::new(exe, cfg.batch, dims), init);
            train_loop(&mut backend, trace, ci, energy, cfg)
        }
        BackendKind::Native => {
            let mut backend = NativeBackend::new(init, cfg.batch);
            train_loop(&mut backend, trace, ci, energy, cfg)
        }
    }
}

/// Train with the pure-Rust backend and no PJRT artifacts at all:
/// deterministic He-uniform initial weights, [`default_dims`]
/// architecture. This is the path CI and artifact-less machines use.
pub fn train_native(
    trace: &Trace,
    ci: &CarbonTrace,
    energy: &EnergyModel,
    cfg: &TrainerConfig,
) -> anyhow::Result<TrainReport> {
    cfg.validate()?;
    let init = QNetParams::he_uniform(default_dims(), cfg.seed);
    let mut backend = NativeBackend::new(init, cfg.batch);
    train_loop(&mut backend, trace, ci, energy, cfg)
}

/// The backend-agnostic training loop: rollouts, replay, gradient steps,
/// target syncs, telemetry. All per-step state (sample buffers, params,
/// moments) is preallocated — the loop itself performs no per-step heap
/// allocation beyond what the backend's own step does.
pub fn train_loop(
    backend: &mut dyn TrainBackend,
    trace: &Trace,
    ci: &CarbonTrace,
    energy: &EnergyModel,
    cfg: &TrainerConfig,
) -> anyhow::Result<TrainReport> {
    cfg.validate()?;

    let mut replay = ReplayBuffer::new(cfg.replay_capacity);
    let mut rng = Rng::new(cfg.seed);
    let mut epsilon = cfg.epsilon_start;
    let mut t_step: u64 = 0;
    let mut episodes = Vec::with_capacity(cfg.episodes);
    let mut batch = SampleBatch::new(cfg.batch);

    // Per-step wall-clock telemetry (µs histogram); the Instant reads are
    // gated on an installed obs sink so the hot loop stays untimed when
    // observability is off.
    let obs_on = crate::obs::enabled();
    let mut step_hist = crate::obs::Hist::new();

    let lambda_grid = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

    // One agent reused across episodes (keeps its pending-map capacity);
    // weights are swapped in per episode from the backend's snapshot.
    let mut agent =
        EpsilonGreedyAgent::new(NativeMlp::from_arc(backend.snapshot()), epsilon, cfg.seed);

    for ep in 0..cfg.episodes {
        let lambda = cfg
            .lambda_carbon
            .unwrap_or_else(|| *rng.choice(&lambda_grid));

        // --- Rollout: ε-greedy over the training trace, function-sharded
        // across cores. The agent's per-function RNG streams and canonical
        // transition drain order make the rollout shard-count-invariant.
        agent.reset_episode();
        agent.reseed(cfg.seed ^ ep as u64);
        agent.epsilon = epsilon;
        agent.set_mlp(NativeMlp::from_arc(backend.snapshot()));
        let sim_cfg = SimConfig { lambda_carbon: lambda, ..SimConfig::default() };
        let sim = ShardedSimulator::new(trace, ci, energy.clone(), sim_cfg);
        let roll_span = crate::obs::span("trainer/rollout");
        sim.run(&mut agent);
        drop(roll_span);
        let episode_reward = agent.episode_reward;
        let transitions = agent.take_transitions();
        let n_tr = transitions.len();
        for t in transitions {
            replay.push(t);
        }

        // --- Gradient steps.
        let mut loss_sum = 0.0f32;
        let mut loss_n = 0u32;
        let grad_t0 = Instant::now();
        if replay.len() >= cfg.batch {
            let _grad_span = crate::obs::span("trainer/gradient-steps");
            for _ in 0..cfg.steps_per_episode {
                replay.sample_batch(&mut rng, &mut batch);
                t_step += 1;
                let step_t0 = obs_on.then(Instant::now);
                let loss = backend.step(t_step, &batch)?;
                if let Some(t0) = step_t0 {
                    step_hist.record(t0.elapsed().as_secs_f64() * 1e6);
                }
                loss_sum += loss;
                loss_n += 1;
                if t_step % cfg.target_sync_steps as u64 == 0 {
                    backend.sync_target();
                }
            }
        }
        let grad_elapsed = grad_t0.elapsed().as_secs_f64();
        let grad_steps_per_s = if loss_n > 0 && grad_elapsed > 0.0 {
            loss_n as f64 / grad_elapsed
        } else {
            0.0
        };

        let stats = EpisodeStats {
            episode: ep,
            epsilon,
            lambda,
            transitions: n_tr,
            mean_loss: if loss_n > 0 { loss_sum / loss_n as f32 } else { f32::NAN },
            episode_reward,
            grad_steps_per_s,
        };
        if cfg.verbose {
            println!(
                "[train] ep {:>3} eps={:.3} lambda={:.1} transitions={:>7} loss={:.5} reward={:.1}",
                stats.episode,
                stats.epsilon,
                stats.lambda,
                stats.transitions,
                stats.mean_loss,
                stats.episode_reward
            );
        }
        episodes.push(stats);
        epsilon = (epsilon * cfg.epsilon_decay).max(cfg.epsilon_min);
    }

    // --- Telemetry: per-episode loss/ε/λ/reward/throughput series plus
    // the per-step latency histogram (no-op when no obs sink installed).
    if let Some(sink) = crate::obs::sink() {
        use crate::util::json::Json;
        sink.add_counter("train/episodes", episodes.len() as u64);
        sink.add_counter("train/gradient_steps", t_step);
        let mut lines = Vec::with_capacity(episodes.len() + 2);
        lines.push(Json::obj(vec![
            ("kind", "meta".into()),
            ("stream", "train".into()),
            ("backend", backend.name().into()),
            ("episodes", (episodes.len() as u64).into()),
            ("gradient_steps", t_step.into()),
        ]));
        for s in &episodes {
            lines.push(Json::obj(vec![
                ("kind", "episode".into()),
                ("episode", (s.episode as u64).into()),
                ("epsilon", s.epsilon.into()),
                ("lambda", s.lambda.into()),
                ("transitions", (s.transitions as u64).into()),
                // NaN when an episode ran no gradient steps (replay still
                // filling) — export as null, not invalid bare NaN.
                ("td_loss", Json::num_or_null(s.mean_loss as f64)),
                ("reward", s.episode_reward.into()),
                ("grad_steps_per_s", s.grad_steps_per_s.into()),
            ]));
        }
        lines.push(step_hist.to_json("step_us"));
        if let Err(e) = sink.emit_jsonl("train", &lines) {
            eprintln!("[obs] failed to write train telemetry: {e}");
        }
    }

    let params = backend.params().clone();
    Ok(TrainReport { params, episodes, total_steps: t_step, backend: backend.name() })
}

/// Train and persist the weights into the artifact directory.
pub fn train_and_save(
    artifacts: &ArtifactSet,
    runtime: &PjrtRuntime,
    trace: &Trace,
    ci: &CarbonTrace,
    energy: &EnergyModel,
    cfg: &TrainerConfig,
) -> anyhow::Result<TrainReport> {
    let report = train(artifacts, runtime, trace, ci, energy, cfg)?;
    let path = artifacts.trained_weights_path();
    crate::rl::weights::save_params(path.to_str().unwrap(), &report.params)?;
    if cfg.verbose {
        println!("[train] saved weights to {}", path.display());
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_and_smoke_configs_validate() {
        TrainerConfig::default().validate().unwrap();
        TrainerConfig::smoke().validate().unwrap();
    }

    #[test]
    fn zero_target_sync_steps_is_rejected() {
        let cfg = TrainerConfig { target_sync_steps: 0, ..TrainerConfig::default() };
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("target_sync_steps"), "unexpected error: {err}");
    }

    #[test]
    fn degenerate_configs_are_rejected() {
        for cfg in [
            TrainerConfig { episodes: 0, ..TrainerConfig::default() },
            TrainerConfig { batch: 0, ..TrainerConfig::default() },
            TrainerConfig { replay_capacity: 8, batch: 64, ..TrainerConfig::default() },
            TrainerConfig { epsilon_decay: 0.0, ..TrainerConfig::default() },
            TrainerConfig { epsilon_decay: 1.5, ..TrainerConfig::default() },
        ] {
            assert!(cfg.validate().is_err(), "{cfg:?} should not validate");
        }
    }

    #[test]
    fn default_dims_match_manifest_convention() {
        let (d, h1, h2, a) = default_dims();
        assert_eq!(d, STATE_DIM);
        assert_eq!((h1, h2), (64, 64));
        assert_eq!(a, crate::KEEP_ALIVE_ACTIONS.len());
    }
}
