//! Rust-side DQN training loop driving the AOT `dqn_train_step` via PJRT.
//!
//! Python is compile-time only: the entire training loop — episodes over
//! the training trace, ε decay, replay sampling, target-network syncs —
//! runs here, with every gradient step executed by the AOT artifact.
//!
//! Schedule (paper §IV-A4 scaled to this testbed): per episode the agent
//! replays the training trace slice with ε-greedy exploration, harvested
//! transitions land in the 10,000-slot replay buffer, then
//! `steps_per_episode` Adam steps are applied (batch 64, lr 1e-3, γ 0.99).
//! The target network syncs every `target_sync_steps` gradient steps, ε
//! decays ×0.95 per episode to 0.05. λ_carbon is sampled per episode so the
//! network learns the preference-conditioned policy (§III-C).

use std::sync::Arc;

use crate::carbon::intensity::CarbonTrace;
use crate::energy::model::EnergyModel;
use crate::policy::native_mlp::NativeMlp;
use crate::rl::agent::EpsilonGreedyAgent;
use crate::rl::encoder::STATE_DIM;
use crate::rl::qnet::QNetParams;
use crate::rl::replay::ReplayBuffer;
use crate::runtime::{ArtifactSet, PjrtRuntime, TrainStep};
use crate::simulator::engine::SimConfig;
use crate::simulator::sharded::ShardedSimulator;
use crate::trace::model::Trace;
use crate::util::rng::Rng;

/// Training hyper-parameters.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub episodes: usize,
    pub steps_per_episode: usize,
    pub replay_capacity: usize,
    pub batch: usize,
    pub epsilon_start: f64,
    pub epsilon_min: f64,
    pub epsilon_decay: f64,
    pub target_sync_steps: usize,
    /// Fixed λ_carbon, or None to sample per episode from {0.1 … 0.9}.
    pub lambda_carbon: Option<f64>,
    pub seed: u64,
    /// Print per-episode progress lines.
    pub verbose: bool,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            episodes: 30,
            steps_per_episode: 800,
            replay_capacity: 10_000,
            batch: 64,
            epsilon_start: 1.0,
            epsilon_min: 0.05,
            epsilon_decay: 0.95,
            target_sync_steps: 500,
            lambda_carbon: None,
            seed: 17,
            verbose: true,
        }
    }
}

impl TrainerConfig {
    /// Tiny schedule for tests.
    pub fn smoke() -> Self {
        TrainerConfig {
            episodes: 2,
            steps_per_episode: 10,
            verbose: false,
            ..TrainerConfig::default()
        }
    }
}

/// Per-episode training statistics.
#[derive(Debug, Clone)]
pub struct EpisodeStats {
    pub episode: usize,
    pub epsilon: f64,
    pub lambda: f64,
    pub transitions: usize,
    pub mean_loss: f32,
    pub episode_reward: f64,
}

/// Final training report.
pub struct TrainReport {
    pub params: QNetParams,
    pub episodes: Vec<EpisodeStats>,
    pub total_steps: u64,
}

/// Train a DQN on `trace` and return the learned parameters.
pub fn train(
    artifacts: &ArtifactSet,
    runtime: &PjrtRuntime,
    trace: &Trace,
    ci: &CarbonTrace,
    energy: &EnergyModel,
    cfg: &TrainerConfig,
) -> anyhow::Result<TrainReport> {
    let dims = artifacts.manifest.dims();
    anyhow::ensure!(cfg.batch == artifacts.manifest.train_batch, "batch mismatch with artifact");

    let exe = runtime.load_hlo_text(artifacts.train_step_path().to_str().unwrap())?;
    let step_exe = TrainStep::new(exe, cfg.batch, dims);

    // Online/target weights live behind `Arc`: a target sync is a pointer
    // copy (snapshots are immutable — gradient steps *replace* the online
    // Arc), and episode rollouts fork the same Arc into shard agents
    // without deep-copying the network.
    let mut params = Arc::new(artifacts.init_params()?);
    let mut target = Arc::clone(&params);
    let mut m = QNetParams::zeros(dims);
    let mut v = QNetParams::zeros(dims);

    let mut replay = ReplayBuffer::new(cfg.replay_capacity);
    let mut rng = Rng::new(cfg.seed);
    let mut epsilon = cfg.epsilon_start;
    let mut t_step: u64 = 0;
    let mut episodes = Vec::with_capacity(cfg.episodes);

    // Flat sample buffers reused across steps.
    let b = cfg.batch;
    let mut s_buf = vec![0.0f32; b * STATE_DIM];
    let mut a_buf = vec![0i32; b];
    let mut r_buf = vec![0.0f32; b];
    let mut ns_buf = vec![0.0f32; b * STATE_DIM];
    let mut d_buf = vec![0.0f32; b];

    let lambda_grid = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

    // One agent reused across episodes (keeps its pending-map capacity);
    // weights are swapped in per episode via the shared Arc.
    let mut agent =
        EpsilonGreedyAgent::new(NativeMlp::from_arc(Arc::clone(&params)), epsilon, cfg.seed);

    for ep in 0..cfg.episodes {
        let lambda = cfg
            .lambda_carbon
            .unwrap_or_else(|| *rng.choice(&lambda_grid));

        // --- Rollout: ε-greedy over the training trace, function-sharded
        // across cores. The agent's per-function RNG streams and canonical
        // transition drain order make the rollout shard-count-invariant.
        agent.reset_episode();
        agent.reseed(cfg.seed ^ ep as u64);
        agent.epsilon = epsilon;
        agent.set_mlp(NativeMlp::from_arc(Arc::clone(&params)));
        let sim_cfg = SimConfig { lambda_carbon: lambda, ..SimConfig::default() };
        let sim = ShardedSimulator::new(trace, ci, energy.clone(), sim_cfg);
        let roll_span = crate::obs::span("trainer/rollout");
        sim.run(&mut agent);
        drop(roll_span);
        let episode_reward = agent.episode_reward;
        let transitions = agent.take_transitions();
        let n_tr = transitions.len();
        for t in transitions {
            replay.push(t);
        }

        // --- Gradient steps.
        let mut loss_sum = 0.0f32;
        let mut loss_n = 0u32;
        if replay.len() >= b {
            let _grad_span = crate::obs::span("trainer/gradient-steps");
            for _ in 0..cfg.steps_per_episode {
                replay.sample_into(
                    &mut rng, b, &mut s_buf, &mut a_buf, &mut r_buf, &mut ns_buf,
                    &mut d_buf,
                );
                t_step += 1;
                let out = step_exe.step(
                    &params,
                    &target,
                    &m,
                    &v,
                    t_step as f32,
                    &s_buf,
                    &a_buf,
                    &r_buf,
                    &ns_buf,
                    &d_buf,
                )?;
                params = Arc::new(out.params);
                m = out.m;
                v = out.v;
                loss_sum += out.loss;
                loss_n += 1;
                if t_step % cfg.target_sync_steps as u64 == 0 {
                    // Pointer copy: the old online snapshot becomes the
                    // target; no parameter deep-clone on the sync path.
                    target = Arc::clone(&params);
                }
            }
        }

        let stats = EpisodeStats {
            episode: ep,
            epsilon,
            lambda,
            transitions: n_tr,
            mean_loss: if loss_n > 0 { loss_sum / loss_n as f32 } else { f32::NAN },
            episode_reward,
        };
        if cfg.verbose {
            println!(
                "[train] ep {:>3} eps={:.3} lambda={:.1} transitions={:>7} loss={:.5} reward={:.1}",
                stats.episode,
                stats.epsilon,
                stats.lambda,
                stats.transitions,
                stats.mean_loss,
                stats.episode_reward
            );
        }
        episodes.push(stats);
        epsilon = (epsilon * cfg.epsilon_decay).max(cfg.epsilon_min);
    }

    // --- Telemetry: per-episode loss/ε/λ/reward series (no-op when no
    // obs sink is installed).
    if let Some(sink) = crate::obs::sink() {
        use crate::util::json::Json;
        sink.add_counter("train/episodes", episodes.len() as u64);
        sink.add_counter("train/gradient_steps", t_step);
        let mut lines = Vec::with_capacity(episodes.len() + 1);
        lines.push(Json::obj(vec![
            ("kind", "meta".into()),
            ("stream", "train".into()),
            ("episodes", (episodes.len() as u64).into()),
            ("gradient_steps", t_step.into()),
        ]));
        for s in &episodes {
            lines.push(Json::obj(vec![
                ("kind", "episode".into()),
                ("episode", (s.episode as u64).into()),
                ("epsilon", s.epsilon.into()),
                ("lambda", s.lambda.into()),
                ("transitions", (s.transitions as u64).into()),
                // NaN when an episode ran no gradient steps (replay still
                // filling) — export as null, not invalid bare NaN.
                ("td_loss", Json::num_or_null(s.mean_loss as f64)),
                ("reward", s.episode_reward.into()),
            ]));
        }
        if let Err(e) = sink.emit_jsonl("train", &lines) {
            eprintln!("[obs] failed to write train telemetry: {e}");
        }
    }

    // Release the other Arc holders (agent's MLP, target snapshot) so the
    // final weights unwrap without a deep clone.
    drop(agent);
    drop(target);
    let params = Arc::try_unwrap(params).unwrap_or_else(|a| (*a).clone());
    Ok(TrainReport { params, episodes, total_steps: t_step })
}

/// Train and persist the weights into the artifact directory.
pub fn train_and_save(
    artifacts: &ArtifactSet,
    runtime: &PjrtRuntime,
    trace: &Trace,
    ci: &CarbonTrace,
    energy: &EnergyModel,
    cfg: &TrainerConfig,
) -> anyhow::Result<TrainReport> {
    let report = train(artifacts, runtime, trace, ci, energy, cfg)?;
    let path = artifacts.trained_weights_path();
    crate::rl::weights::save_params(path.to_str().unwrap(), &report.params)?;
    if cfg.verbose {
        println!("[train] saved weights to {}", path.display());
    }
    Ok(report)
}
