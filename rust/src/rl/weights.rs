//! LACEW001 weight-file I/O — the binary format shared with
//! `python/compile/aot.py::write_weights` (change in lockstep).
//!
//! Layout (little-endian):
//! `magic[8] | u32 n | n × ( u32 name_len | name | u32 ndim | u32 dims[] |
//! f32 data[] )`

use std::io::{Read, Write};

use crate::rl::qnet::QNetParams;

pub const MAGIC: &[u8; 8] = b"LACEW001";

/// Named tensor list as stored on disk.
pub type NamedTensors = Vec<(String, Vec<usize>, Vec<f32>)>;

/// Read every tensor from a LACEW001 stream.
pub fn read_tensors<R: Read>(mut r: R) -> anyhow::Result<NamedTensors> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad magic: {magic:?}");
    let n = read_u32(&mut r)? as usize;
    anyhow::ensure!(n <= 1024, "implausible tensor count {n}");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(&mut r)? as usize;
        anyhow::ensure!(name_len <= 256, "implausible name length {name_len}");
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let ndim = read_u32(&mut r)? as usize;
        anyhow::ensure!(ndim <= 8, "implausible rank {ndim}");
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut r)? as usize);
        }
        let count: usize = dims.iter().product::<usize>().max(1);
        anyhow::ensure!(count <= 64 << 20, "implausible tensor size {count}");
        let mut bytes = vec![0u8; count * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push((name, dims, data));
    }
    Ok(out)
}

/// Write tensors to a LACEW001 stream.
pub fn write_tensors<W: Write>(mut w: W, tensors: &NamedTensors) -> anyhow::Result<()> {
    w.write_all(MAGIC)?;
    write_u32(&mut w, tensors.len() as u32)?;
    for (name, dims, data) in tensors {
        let expect: usize = dims.iter().product::<usize>().max(1);
        anyhow::ensure!(expect == data.len(), "tensor '{name}' shape/data mismatch");
        write_u32(&mut w, name.len() as u32)?;
        w.write_all(name.as_bytes())?;
        write_u32(&mut w, dims.len() as u32)?;
        for &d in dims {
            write_u32(&mut w, d as u32)?;
        }
        for &v in data {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Load Q-network parameters from a weight file.
pub fn load_params(path: &str) -> anyhow::Result<QNetParams> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {path}: {e}"))?;
    let named = read_tensors(std::io::BufReader::new(f))?;
    QNetParams::from_named(&named)
}

/// Save Q-network parameters to a weight file.
pub fn save_params(path: &str, params: &QNetParams) -> anyhow::Result<()> {
    let named: NamedTensors = params
        .tensors()
        .iter()
        .map(|(n, s, d)| (n.to_string(), s.clone(), (*d).clone()))
        .collect();
    let f = std::fs::File::create(path)?;
    write_tensors(std::io::BufWriter::new(f), &named)
}

fn read_u32<R: Read>(r: &mut R) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn write_u32<W: Write>(w: &mut W, v: u32) -> anyhow::Result<()> {
    Ok(w.write_all(&v.to_le_bytes())?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_in_memory() {
        let p = {
            let mut p = QNetParams::zeros((3, 4, 4, 2));
            p.w1[0] = 1.5;
            p.b3[1] = -2.25;
            p
        };
        let named: NamedTensors = p
            .tensors()
            .iter()
            .map(|(n, s, d)| (n.to_string(), s.clone(), (*d).clone()))
            .collect();
        let mut buf = Vec::new();
        write_tensors(&mut buf, &named).unwrap();
        let back = read_tensors(buf.as_slice()).unwrap();
        let q = QNetParams::from_named(&back).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = b"NOTMAGIC\x00\x00\x00\x00".to_vec();
        assert!(read_tensors(buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_shape_mismatch_on_write() {
        let named: NamedTensors = vec![("x".into(), vec![2, 2], vec![1.0; 3])];
        let mut buf = Vec::new();
        assert!(write_tensors(&mut buf, &named).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let p = QNetParams::zeros((10, 64, 64, 5));
        let path = std::env::temp_dir().join("lace_rl_weights_test.bin");
        let path = path.to_str().unwrap();
        save_params(path, &p).unwrap();
        let q = load_params(path).unwrap();
        assert_eq!(p, q);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn reads_python_written_init_weights_if_present() {
        // Cross-language check against the artifact the AOT build wrote.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/init_weights.bin");
        if !std::path::Path::new(path).exists() {
            return; // artifacts not built in this environment
        }
        let p = load_params(path).unwrap();
        assert_eq!(p.dims, (10, 64, 64, 5));
        // He-uniform bound on w1: sqrt(6/10)
        let bound = (6.0f32 / 10.0).sqrt() + 1e-6;
        assert!(p.w1.iter().all(|w| w.abs() <= bound));
        assert!(p.b1.iter().all(|&b| b == 0.0));
    }
}
