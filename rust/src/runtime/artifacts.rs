//! Artifact discovery + manifest parsing.
//!
//! `make artifacts` populates `artifacts/` with the HLO-text executables,
//! deterministic init weights, and a JSON manifest describing the network
//! dims and hyper-parameters. This module is the single source of truth
//! for artifact paths and manifest consistency checks.

use std::path::{Path, PathBuf};

use crate::rl::qnet::QNetParams;
use crate::util::json::Json;

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub state_dim: usize,
    pub hidden: (usize, usize),
    pub n_actions: usize,
    pub actions_sec: Vec<f64>,
    pub train_batch: usize,
    pub gamma: f64,
    pub lr: f64,
    pub infer_batches: Vec<usize>,
}

impl Manifest {
    pub fn parse(src: &str) -> anyhow::Result<Manifest> {
        let j = Json::parse(src)?;
        let usize_field = |k: &str| -> anyhow::Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("manifest missing '{k}'"))
        };
        let hidden = j
            .get("hidden")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'hidden'"))?;
        anyhow::ensure!(hidden.len() == 2, "expected 2 hidden sizes");
        let arr_f64 = |k: &str| -> anyhow::Result<Vec<f64>> {
            j.get(k)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .ok_or_else(|| anyhow::anyhow!("manifest missing '{k}'"))
        };
        Ok(Manifest {
            state_dim: usize_field("state_dim")?,
            hidden: (
                hidden[0].as_usize().unwrap_or(0),
                hidden[1].as_usize().unwrap_or(0),
            ),
            n_actions: usize_field("n_actions")?,
            actions_sec: arr_f64("actions_sec")?,
            train_batch: usize_field("train_batch")?,
            gamma: j.get("gamma").and_then(Json::as_f64).unwrap_or(0.99),
            lr: j.get("lr").and_then(Json::as_f64).unwrap_or(1e-3),
            infer_batches: arr_f64("infer_batches")?
                .into_iter()
                .map(|x| x as usize)
                .collect(),
        })
    }

    /// Network dims tuple used by [`QNetParams`].
    pub fn dims(&self) -> (usize, usize, usize, usize) {
        (self.state_dim, self.hidden.0, self.hidden.1, self.n_actions)
    }
}

/// The artifact directory with validated manifest.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl ArtifactSet {
    /// Open and validate `dir` (defaults used by the CLI: `./artifacts`).
    pub fn open(dir: &str) -> anyhow::Result<ArtifactSet> {
        let dir = PathBuf::from(dir);
        let mpath = dir.join("manifest.json");
        let src = std::fs::read_to_string(&mpath)
            .map_err(|e| anyhow::anyhow!("read {}: {e} (run `make artifacts`)", mpath.display()))?;
        let manifest = Manifest::parse(&src)?;
        anyhow::ensure!(
            manifest.actions_sec == crate::KEEP_ALIVE_ACTIONS.to_vec(),
            "artifact action set {:?} != crate KEEP_ALIVE_ACTIONS {:?}",
            manifest.actions_sec,
            crate::KEEP_ALIVE_ACTIONS
        );
        anyhow::ensure!(
            manifest.state_dim == crate::rl::encoder::STATE_DIM,
            "artifact state_dim {} != encoder STATE_DIM {}",
            manifest.state_dim,
            crate::rl::encoder::STATE_DIM
        );
        let a = ArtifactSet { dir, manifest };
        for p in [
            a.infer_path(1),
            a.train_step_path(),
            a.init_weights_path(),
        ] {
            anyhow::ensure!(p.exists(), "missing artifact {}", p.display());
        }
        Ok(a)
    }

    pub fn infer_path(&self, batch: usize) -> PathBuf {
        self.dir.join(format!("dqn_infer_b{batch}.hlo.txt"))
    }

    pub fn infer_jnp_path(&self, batch: usize) -> PathBuf {
        self.dir.join(format!("dqn_infer_jnp_b{batch}.hlo.txt"))
    }

    pub fn train_step_path(&self) -> PathBuf {
        self.dir.join("dqn_train_step.hlo.txt")
    }

    pub fn init_weights_path(&self) -> PathBuf {
        self.dir.join("init_weights.bin")
    }

    /// Path where trained weights are stored by the trainer.
    pub fn trained_weights_path(&self) -> PathBuf {
        self.dir.join("trained_weights.bin")
    }

    /// Load the deterministic init parameters.
    pub fn init_params(&self) -> anyhow::Result<QNetParams> {
        let p = crate::rl::weights::load_params(
            self.init_weights_path().to_str().unwrap(),
        )?;
        anyhow::ensure!(p.dims == self.manifest.dims(), "init weights dims mismatch");
        Ok(p)
    }

    /// Load trained weights if present, else the init weights.
    pub fn best_params(&self) -> anyhow::Result<QNetParams> {
        let trained = self.trained_weights_path();
        if trained.exists() {
            crate::rl::weights::load_params(trained.to_str().unwrap())
        } else {
            self.init_params()
        }
    }
}

/// Default artifact directory relative to the repo root.
pub fn default_dir() -> String {
    // Respect LACE_RL_ARTIFACTS for tests/CI; fall back to ./artifacts or
    // the crate-relative path when running from elsewhere.
    if let Ok(d) = std::env::var("LACE_RL_ARTIFACTS") {
        return d;
    }
    if Path::new("artifacts/manifest.json").exists() {
        return "artifacts".to_string();
    }
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "state_dim": 10, "hidden": [64, 64], "n_actions": 5,
      "actions_sec": [1.0, 5.0, 10.0, 30.0, 60.0],
      "train_batch": 64, "gamma": 0.99, "lr": 0.001,
      "adam": [0.9, 0.999, 1e-8], "huber_delta": 1.0,
      "param_keys": ["w1","b1","w2","b2","w3","b3"],
      "infer_batches": [1, 256], "seed": 0
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(MANIFEST).unwrap();
        assert_eq!(m.dims(), (10, 64, 64, 5));
        assert_eq!(m.actions_sec, vec![1.0, 5.0, 10.0, 30.0, 60.0]);
        assert_eq!(m.train_batch, 64);
        assert_eq!(m.infer_batches, vec![1, 256]);
    }

    #[test]
    fn missing_field_errors() {
        assert!(Manifest::parse("{}").is_err());
    }

    #[test]
    fn open_real_artifacts_if_present() {
        let dir = default_dir();
        if !Path::new(&dir).join("manifest.json").exists() {
            return;
        }
        let a = ArtifactSet::open(&dir).unwrap();
        assert_eq!(a.manifest.dims(), (10, 64, 64, 5));
        let p = a.init_params().unwrap();
        assert_eq!(p.dims, (10, 64, 64, 5));
    }
}
