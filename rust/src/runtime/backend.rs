//! PJRT implementation of [`TrainBackend`]: drives the AOT-compiled
//! `dqn_train_step` executable with double-buffered host state.
//!
//! Earlier trainer versions rebuilt `Arc<QNetParams>` and moved fresh
//! `m`/`v` tensors out of the executable wrapper on every gradient step.
//! Here the online params and both Adam moments live in two preallocated
//! [`TrainState`] buffers: each step decodes the executable outputs into
//! the spare buffer ([`TrainStep::step_into`]) and swaps — no per-step
//! `QNetParams::zeros`. The literal-decode `Vec`s inside the `xla` crate
//! boundary are the one remaining allocation (the fully allocation-free
//! path is [`crate::rl::native_train::NativeBackend`]).

use crate::rl::backend::TrainBackend;
use crate::rl::qnet::QNetParams;
use crate::rl::replay::SampleBatch;
use crate::runtime::executable::TrainStep;
use std::sync::Arc;

/// One buffer generation: online params + Adam first/second moments.
#[derive(Debug)]
struct TrainState {
    p: QNetParams,
    m: QNetParams,
    v: QNetParams,
}

impl TrainState {
    fn zeros(dims: (usize, usize, usize, usize)) -> Self {
        TrainState {
            p: QNetParams::zeros(dims),
            m: QNetParams::zeros(dims),
            v: QNetParams::zeros(dims),
        }
    }
}

/// [`TrainBackend`] over the PJRT `dqn_train_step` executable.
pub struct PjrtBackend {
    exe: TrainStep,
    /// Current generation (read side of the next step).
    cur: TrainState,
    /// Spare generation the next step decodes into before the swap.
    next: TrainState,
    target: QNetParams,
}

impl PjrtBackend {
    /// Start from `init` (online and target both set to it, zero moments).
    pub fn new(exe: TrainStep, init: QNetParams) -> Self {
        let dims = init.dims;
        let mut cur = TrainState::zeros(dims);
        cur.p.copy_from(&init);
        PjrtBackend { exe, cur, next: TrainState::zeros(dims), target: init }
    }

    /// Adam moments (cross-backend agreement tests).
    pub fn moments(&self) -> (&QNetParams, &QNetParams) {
        (&self.cur.m, &self.cur.v)
    }
}

impl TrainBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn step(&mut self, t: u64, batch: &SampleBatch) -> anyhow::Result<f32> {
        let loss = self.exe.step_into(
            &self.cur.p,
            &self.target,
            &self.cur.m,
            &self.cur.v,
            t as f32,
            batch,
            &mut self.next.p,
            &mut self.next.m,
            &mut self.next.v,
        )?;
        std::mem::swap(&mut self.cur, &mut self.next);
        Ok(loss)
    }

    fn sync_target(&mut self) {
        self.target.copy_from(&self.cur.p);
    }

    fn snapshot(&self) -> Arc<QNetParams> {
        Arc::new(self.cur.p.clone())
    }

    fn params(&self) -> &QNetParams {
        &self.cur.p
    }
}
