//! PJRT client + generic executable wrapper.

use anyhow::Context;

/// A PJRT CPU client owning compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create the CPU client (one per process is plenty; compilation is
    /// cached per executable, not per call).
    pub fn cpu() -> anyhow::Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: &str) -> anyhow::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path}"))?;
        Ok(Executable { exe, path: path.to_string() })
    }
}

/// One compiled computation. All our artifacts are lowered with
/// `return_tuple=True`, so the single output is a tuple that `run`
/// decomposes into per-output literals.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    path: String,
}

impl Executable {
    /// Execute with the given input literals; returns the decomposed
    /// output tuple transferred to host.
    pub fn run(&self, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.path))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .context("transferring result to host")?;
        Ok(lit.to_tuple()?)
    }

    pub fn path(&self) -> &str {
        &self.path
    }
}

/// Host-side tensor helpers.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> anyhow::Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    if dims.len() == 1 {
        debug_assert_eq!(dims[0], data.len());
        return Ok(lit);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims_i64)?)
}

pub fn literal_i32(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

pub fn literal_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Copy a literal back to an f32 vec.
pub fn to_f32_vec(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
