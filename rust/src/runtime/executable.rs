//! Typed wrappers over the AOT executables: Q-network inference and the
//! full DQN train step. Input/output layouts mirror
//! `python/compile/model.py` (flat signature documented on
//! `dqn_train_step`).

use crate::rl::qnet::QNetParams;
use crate::rl::replay::SampleBatch;
use crate::runtime::client::{
    literal_f32, literal_i32, literal_scalar_f32, to_f32_vec, Executable,
};

/// Convert params to the 6 input literals in PARAM_KEYS order.
fn param_literals(p: &QNetParams) -> anyhow::Result<Vec<xla::Literal>> {
    p.tensors()
        .iter()
        .map(|(_, shape, data)| literal_f32(data, shape))
        .collect()
}

/// Copy 6 consecutive output literals back into a [`QNetParams`].
fn params_from_literals(
    lits: &[xla::Literal],
    dims: (usize, usize, usize, usize),
) -> anyhow::Result<QNetParams> {
    anyhow::ensure!(lits.len() >= 6, "expected ≥6 literals");
    let mut p = QNetParams::zeros(dims);
    for (dst, lit) in p.tensors_mut().into_iter().zip(lits.iter()) {
        let v = to_f32_vec(lit)?;
        anyhow::ensure!(v.len() == dst.len(), "tensor size mismatch");
        *dst = v;
    }
    Ok(p)
}

/// Copy 6 consecutive output literals into an existing [`QNetParams`],
/// reusing its buffers (the per-step path of
/// [`crate::runtime::backend::PjrtBackend`] — no fresh `zeros` per step;
/// the decode `Vec` from the literal API is the one allocation left).
fn params_from_literals_into(lits: &[xla::Literal], p: &mut QNetParams) -> anyhow::Result<()> {
    anyhow::ensure!(lits.len() >= 6, "expected ≥6 literals");
    for (dst, lit) in p.tensors_mut().into_iter().zip(lits.iter()) {
        let v = to_f32_vec(lit)?;
        anyhow::ensure!(v.len() == dst.len(), "tensor size mismatch");
        dst.copy_from_slice(&v);
    }
    Ok(())
}

/// Batched Q-network inference executable (`dqn_infer_b{N}.hlo.txt`).
pub struct QNetInfer {
    exe: Executable,
    pub batch: usize,
    dims: (usize, usize, usize, usize),
}

impl QNetInfer {
    pub fn new(exe: Executable, batch: usize, dims: (usize, usize, usize, usize)) -> Self {
        QNetInfer { exe, batch, dims }
    }

    /// Q-values for `batch` states. `states` is row-major
    /// `[batch * state_dim]`; returns `[batch * n_actions]`.
    pub fn q_values(&self, params: &QNetParams, states: &[f32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            states.len() == self.batch * self.dims.0,
            "states length {} != batch {} × state_dim {}",
            states.len(),
            self.batch,
            self.dims.0
        );
        let mut inputs = param_literals(params)?;
        inputs.push(literal_f32(states, &[self.batch, self.dims.0])?);
        let out = self.exe.run(&inputs)?;
        anyhow::ensure!(out.len() == 1, "expected 1 output, got {}", out.len());
        let q = to_f32_vec(&out[0])?;
        anyhow::ensure!(q.len() == self.batch * self.dims.3, "q shape mismatch");
        Ok(q)
    }
}

/// The AOT DQN + Adam train step (`dqn_train_step.hlo.txt`).
///
/// One call = one gradient step: samples are provided as flat arrays, the
/// returned params/moments replace the host copies. Pure function — the
/// caller owns all state, so training is resumable and deterministic.
pub struct TrainStep {
    exe: Executable,
    pub batch: usize,
    dims: (usize, usize, usize, usize),
}

/// Result of one train step.
pub struct StepOut {
    pub params: QNetParams,
    pub m: QNetParams,
    pub v: QNetParams,
    pub loss: f32,
}

impl TrainStep {
    pub fn new(exe: Executable, batch: usize, dims: (usize, usize, usize, usize)) -> Self {
        TrainStep { exe, batch, dims }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        params: &QNetParams,
        target: &QNetParams,
        m: &QNetParams,
        v: &QNetParams,
        t: f32,
        states: &[f32],
        actions: &[i32],
        rewards: &[f32],
        next_states: &[f32],
        dones: &[f32],
    ) -> anyhow::Result<StepOut> {
        let b = self.batch;
        let d = self.dims.0;
        anyhow::ensure!(states.len() == b * d && next_states.len() == b * d);
        anyhow::ensure!(actions.len() == b && rewards.len() == b && dones.len() == b);

        let mut inputs = Vec::with_capacity(30);
        inputs.extend(param_literals(params)?);
        inputs.extend(param_literals(target)?);
        inputs.extend(param_literals(m)?);
        inputs.extend(param_literals(v)?);
        inputs.push(literal_scalar_f32(t));
        inputs.push(literal_f32(states, &[b, d])?);
        inputs.push(literal_i32(actions));
        inputs.push(literal_f32(rewards, &[b])?);
        inputs.push(literal_f32(next_states, &[b, d])?);
        inputs.push(literal_f32(dones, &[b])?);

        let out = self.exe.run(&inputs)?;
        anyhow::ensure!(out.len() == 19, "expected 19 outputs, got {}", out.len());
        Ok(StepOut {
            params: params_from_literals(&out[0..6], self.dims)?,
            m: params_from_literals(&out[6..12], self.dims)?,
            v: params_from_literals(&out[12..18], self.dims)?,
            loss: to_f32_vec(&out[18])?
                .first()
                .copied()
                .ok_or_else(|| anyhow::anyhow!("empty loss output"))?,
        })
    }

    /// Like [`step`](Self::step), but samples come from a [`SampleBatch`]
    /// and the returned params/moments are written into existing buffers
    /// instead of freshly-allocated [`QNetParams`] — the per-step path of
    /// [`crate::runtime::backend::PjrtBackend`]. Returns the loss.
    #[allow(clippy::too_many_arguments)]
    pub fn step_into(
        &self,
        params: &QNetParams,
        target: &QNetParams,
        m: &QNetParams,
        v: &QNetParams,
        t: f32,
        batch: &SampleBatch,
        out_params: &mut QNetParams,
        out_m: &mut QNetParams,
        out_v: &mut QNetParams,
    ) -> anyhow::Result<f32> {
        let b = self.batch;
        let d = self.dims.0;
        anyhow::ensure!(
            batch.batch == b,
            "SampleBatch size {} != executable batch {b}",
            batch.batch
        );
        anyhow::ensure!(batch.states.len() == b * d && batch.next_states.len() == b * d);

        let mut inputs = Vec::with_capacity(30);
        inputs.extend(param_literals(params)?);
        inputs.extend(param_literals(target)?);
        inputs.extend(param_literals(m)?);
        inputs.extend(param_literals(v)?);
        inputs.push(literal_scalar_f32(t));
        inputs.push(literal_f32(&batch.states, &[b, d])?);
        inputs.push(literal_i32(&batch.actions));
        inputs.push(literal_f32(&batch.rewards, &[b])?);
        inputs.push(literal_f32(&batch.next_states, &[b, d])?);
        inputs.push(literal_f32(&batch.dones, &[b])?);

        let out = self.exe.run(&inputs)?;
        anyhow::ensure!(out.len() == 19, "expected 19 outputs, got {}", out.len());
        params_from_literals_into(&out[0..6], out_params)?;
        params_from_literals_into(&out[6..12], out_m)?;
        params_from_literals_into(&out[12..18], out_v)?;
        to_f32_vec(&out[18])?
            .first()
            .copied()
            .ok_or_else(|| anyhow::anyhow!("empty loss output"))
    }
}
