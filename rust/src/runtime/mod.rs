//! PJRT runtime: load AOT HLO-text artifacts, compile, execute.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`). HLO *text* is
//! the interchange format — the crate's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §2).

pub mod artifacts;
pub mod backend;
pub mod client;
pub mod executable;

pub use artifacts::ArtifactSet;
pub use backend::PjrtBackend;
pub use client::{Executable, PjrtRuntime};
pub use executable::{QNetInfer, TrainStep};
