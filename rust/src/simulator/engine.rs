//! The trace-driven simulation engine.
//!
//! Replays invocations in arrival order against per-function warm pools.
//! For every invocation: lazily expire pods, serve warm or cold, account
//! energy/carbon (CI-integrated idle spans), then consult the policy at pod
//! completion for the next keep-alive timeout. Realized outcomes of past
//! decisions are reported back through [`KeepAlivePolicy::observe`] *before*
//! the same function's next `decide` call — the ordering the RL trainer
//! relies on to chain transitions.
//!
//! Semantics notes (see DESIGN.md §7):
//! * Warm-pool selection is most-recently-used.
//! * A cold start's latency penalty is attributed to exactly one pod: the
//!   one of the same function that expired most recently at/before this
//!   arrival and was resolved at this arrival (ties on `warm_until` charge
//!   the last-drained pod only); earlier-resolved expiries are not
//!   retro-charged (documented approximation).
//! * End-of-trace flush charges idle carbon up to min(warm_until, t_end)
//!   and resolves remaining decisions with `done = true`.
//!
//! ## Shard semantics
//!
//! All per-invocation state — warm pods, reuse windows, last-completion
//! times, and the metric sums they feed — is keyed by function id; the
//! per-function MDP (§III) has no cross-function coupling except (a) the
//! order in which f64 metrics are accumulated and (b) the global end time
//! `t_end` that bounds the end-of-trace flush. The engine therefore runs as
//! a [`ShardPass`] over a contiguous function-id range: the sequential
//! [`Simulator::run`] uses one pass over `0..nf`, and
//! `simulator::sharded::ShardedSimulator` runs one pass per shard on its
//! own thread against a policy obtained from `KeepAlivePolicy::fork` (see
//! the fork contract on that trait). Both paths accumulate per-function
//! partial [`SimMetrics`] and fold them in ascending function-id order, and
//! both flush against the global `t_end` — which is why sharded results are
//! bit-identical to sequential ones. Telemetry (`crate::obs`) rides the
//! same contract: per-function accumulators recorded adjacent to each
//! metrics update, folded in the same id order, so collected telemetry is
//! shard-count-invariant too.

use crate::carbon::intensity::CarbonTrace;
use crate::energy::model::EnergyModel;
use crate::obs::{ShardObs, SimObs};
use crate::policy::{DecisionContext, KeepAlivePolicy, Outcome};
use crate::simulator::metrics::SimMetrics;
use crate::simulator::pod::{Pending, Pod};
use crate::simulator::reuse::{ReuseWindow, DEFAULT_WINDOW};
use crate::trace::model::Trace;
use crate::KEEP_ALIVE_ACTIONS;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// User trade-off weight λ_carbon handed to the policy (§III-B).
    pub lambda_carbon: f64,
    /// Constant network latency added to every invocation (s).
    pub network_latency_s: f64,
    /// Reuse-window length W per function.
    pub reuse_window: usize,
    /// Record every end-to-end latency (for percentile reporting).
    pub track_latencies: bool,
    /// Populate the clairvoyant `next_arrival_gap` (Oracle runs only).
    pub provide_oracle_gap: bool,
    /// Collect structured telemetry into [`SimResult::obs`] for this run
    /// even without a global sink. Collection is also on — regardless of
    /// this flag — whenever a process-wide sink is installed
    /// (`obs::install_jsonl`); collecting changes no simulation output bit
    /// (property-tested in `rust/tests/property_obs.rs`).
    pub collect_obs: bool,
    /// Fault injector; `None` (the default) is byte-identical to a build
    /// without the chaos subsystem (`rust/tests/property_chaos.rs`). The
    /// injector is stateless per event, so sharded runs stay bit-identical
    /// to sequential ones under any plan.
    pub chaos: Option<std::sync::Arc<crate::chaos::ChaosInjector>>,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            lambda_carbon: 0.5,
            network_latency_s: crate::NETWORK_LATENCY_S,
            reuse_window: DEFAULT_WINDOW,
            track_latencies: false,
            provide_oracle_gap: false,
            collect_obs: false,
            chaos: None,
        }
    }
}

/// Result of one run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub metrics: SimMetrics,
    /// Per-invocation E2E latencies when `track_latencies` is set.
    pub latencies: Vec<f64>,
    /// Merged telemetry when collection is on (`SimConfig::collect_obs` or
    /// an installed `obs` sink); `None` otherwise.
    pub obs: Option<SimObs>,
}

/// The simulator: borrows a trace + CI trace + energy model, runs policies.
pub struct Simulator<'a> {
    pub trace: &'a Trace,
    pub ci: &'a CarbonTrace,
    pub energy: EnergyModel,
    pub cfg: SimConfig,
}

/// Precompute, for each invocation index, the arrival time of the same
/// function's next invocation (INFINITY if none). The value at index `i`
/// depends only on invocations of the same function, so a pass over a
/// shard's sub-stream reads the same numbers the sequential run does.
pub(crate) fn next_arrival_times(trace: &Trace) -> Vec<f64> {
    let n = trace.invocations.len();
    let mut next = vec![f64::INFINITY; n];
    let mut last_idx: Vec<Option<usize>> = vec![None; trace.functions.len()];
    for (i, inv) in trace.invocations.iter().enumerate() {
        let f = inv.func as usize;
        if let Some(prev) = last_idx[f] {
            next[prev] = inv.t;
        }
        last_idx[f] = Some(i);
    }
    next
}

/// All simulation state of one function: warm pods, the sliding reuse
/// window, the last completion time, and this function's partial metrics.
struct FuncState {
    pods: Vec<Pod>,
    window: ReuseWindow,
    last_completion: f64,
    metrics: SimMetrics,
}

/// One replay pass over a contiguous function-id range (see the module
/// docs). `step` consumes invocations of functions in `f_lo..f_lo+len` in
/// arrival order; `flush` resolves leftover pods against the *global*
/// `t_end`; `collect` folds the per-function partials in function-id order.
pub(crate) struct ShardPass<'a> {
    trace: &'a Trace,
    ci: &'a CarbonTrace,
    energy: &'a EnergyModel,
    cfg: &'a SimConfig,
    f_lo: usize,
    funcs: Vec<FuncState>,
    // Scratch buffer for just-expired decisions, reused across
    // invocations — the hot loop allocates nothing per arrival.
    expired: Vec<(Pending, f64, f64, f64)>, // (pending, warm_until, idle_carbon, span)
    // Telemetry accumulators, `Some` only when collection is on; every
    // recording site below is a null-check when off.
    obs: Option<ShardObs>,
    /// Latest completion time seen by this pass.
    pub(crate) t_end: f64,
}

impl<'a> ShardPass<'a> {
    pub(crate) fn new(
        trace: &'a Trace,
        ci: &'a CarbonTrace,
        energy: &'a EnergyModel,
        cfg: &'a SimConfig,
        funcs: std::ops::Range<usize>,
    ) -> ShardPass<'a> {
        let f_lo = funcs.start;
        let n = funcs.len();
        let states = funcs
            .map(|_| FuncState {
                pods: Vec::new(),
                window: ReuseWindow::new(cfg.reuse_window),
                last_completion: f64::NEG_INFINITY,
                metrics: SimMetrics::new(),
            })
            .collect();
        let obs = if cfg.collect_obs || crate::obs::enabled() {
            Some(ShardObs::new(f_lo, n))
        } else {
            None
        };
        ShardPass {
            trace,
            ci,
            energy,
            cfg,
            f_lo,
            funcs: states,
            expired: Vec::new(),
            obs,
            t_end: 0.0,
        }
    }

    /// Replay one invocation; returns its end-to-end latency.
    /// `next_arrival_t` is the same function's next arrival time (INFINITY
    /// if none); only read when `provide_oracle_gap` is set.
    pub(crate) fn step(
        &mut self,
        policy: &mut dyn KeepAlivePolicy,
        inv: &crate::trace::model::Invocation,
        next_arrival_t: f64,
    ) -> f64 {
        let f = inv.func as usize;
        let prof = &self.trace.functions[f];
        let t = inv.t;
        let active_w = self.energy.active_power_w(prof.mem_mb, prof.cpu_cores);
        let idle_w = self.energy.lambda_idle * active_w;
        let st = &mut self.funcs[f - self.f_lo];

        // (1) Observe the reuse gap from the previous completion.
        if st.last_completion > f64::NEG_INFINITY {
            st.window.push((t - st.last_completion).max(0.0));
        }

        // (2) Lazily expire pods; remember this arrival's expiries for
        //     cold-penalty attribution. (`expired` is drained below, so
        //     it is always empty here.)
        let mut i = 0;
        while i < st.pods.len() {
            if st.pods[i].expired(t) {
                let pod = st.pods.swap_remove(i);
                let span = (pod.warm_until - pod.idle_start).max(0.0);
                let span_carbon = idle_w
                    * self.ci.integrate(pod.idle_start, pod.warm_until)
                    / crate::energy::JOULES_PER_KWH;
                st.metrics.keepalive_carbon_g += span_carbon;
                st.metrics.idle_pod_seconds += span;
                st.metrics.wasted_idle_seconds += span;
                if let Some(o) = self.obs.as_mut() {
                    // Bucketed at the expiry time (warm_until), which can
                    // trail the arrival clock — the accumulator handles
                    // out-of-order inserts.
                    o.func(f).on_expiry(pod.warm_until, span_carbon);
                }
                if let Some(p) = pod.pending {
                    self.expired.push((p, pod.warm_until, span_carbon, span));
                }
            } else {
                i += 1;
            }
        }

        // (3) Serve: MRU warm pod or cold start.
        let mut chosen: Option<usize> = None;
        let mut best_idle_start = f64::NEG_INFINITY;
        for (pi, pod) in st.pods.iter().enumerate() {
            if pod.available(t) && pod.idle_start > best_idle_start {
                best_idle_start = pod.idle_start;
                chosen = Some(pi);
            }
        }

        let (is_cold, cold_lat, pod_idx) = match chosen {
            Some(pi) => {
                // Warm start: close the idle period [idle_start, t].
                let pod = &mut st.pods[pi];
                let idle_carbon = idle_w
                    * self.ci.integrate(pod.idle_start, t)
                    / crate::energy::JOULES_PER_KWH;
                st.metrics.keepalive_carbon_g += idle_carbon;
                st.metrics.idle_pod_seconds += t - pod.idle_start;
                if let Some(o) = self.obs.as_mut() {
                    o.func(f).on_warm(t, idle_carbon);
                }
                if let Some(p) = pod.pending.take() {
                    policy.observe(&Outcome {
                        func: inv.func,
                        action: p.action,
                        t: p.t,
                        resolved_t: t,
                        reused: true,
                        idle_span_s: t - pod.idle_start,
                        idle_carbon_g: idle_carbon,
                        cold_penalty_s: 0.0,
                        done: false,
                    });
                }
                (false, 0.0, pi)
            }
            None => {
                // Cold start. Inside a spawn-failure window the boot is
                // preceded by the recovery policy's retry backoff; the boot
                // itself (and its carbon) is unchanged, just shifted.
                let (retry_delay, retries) = match self.cfg.chaos.as_deref() {
                    Some(ch) => ch.spawn_delay(inv.func, t),
                    None => (0.0, 0),
                };
                let (cold_lat, boot_t) = if retries > 0 {
                    st.metrics.chaos.spawn_retries += u64::from(retries);
                    st.metrics.chaos.retry_delay_s += retry_delay;
                    if let Some(o) = self.obs.as_mut() {
                        o.func(f).on_spawn_retry(u64::from(retries), retry_delay);
                    }
                    (prof.cold_start_s + retry_delay, t + retry_delay)
                } else {
                    (prof.cold_start_s, t)
                };
                st.metrics.cold_carbon_g += self.energy.cold_carbon_g(
                    prof.mem_mb,
                    prof.cpu_cores,
                    boot_t,
                    prof.cold_start_s,
                    self.ci,
                );
                st.pods.push(Pod::new_busy(t + cold_lat + inv.exec_s));
                (true, cold_lat, st.pods.len() - 1)
            }
        };

        // Resolve this arrival's just-expired decisions: exactly one — the
        // most recent expiry (ties on warm_until: the last drained) — is
        // charged the cold start it failed to prevent (if any).
        if !self.expired.is_empty() {
            let mut charged = usize::MAX;
            if is_cold {
                let mut best = f64::NEG_INFINITY;
                for (ei, (_, wu, _, _)) in self.expired.iter().enumerate() {
                    if *wu >= best {
                        best = *wu;
                        charged = ei;
                    }
                }
            }
            for (ei, (p, _, idle_carbon, span)) in self.expired.drain(..).enumerate() {
                let penalty = if ei == charged { cold_lat } else { 0.0 };
                policy.observe(&Outcome {
                    func: inv.func,
                    action: p.action,
                    t: p.t,
                    resolved_t: t,
                    reused: false,
                    idle_span_s: span,
                    idle_carbon_g: idle_carbon,
                    cold_penalty_s: penalty,
                    done: false,
                });
            }
        }

        // (4) Execution accounting.
        let completion = t + cold_lat + inv.exec_s;
        st.metrics.exec_carbon_g += self.energy.exec_carbon_g(
            prof.mem_mb,
            prof.cpu_cores,
            t + cold_lat,
            inv.exec_s,
            self.ci,
        );
        st.metrics.invocations += 1;
        if is_cold {
            st.metrics.cold_starts += 1;
            st.metrics.cold_latency_s += cold_lat;
            if let Some(o) = self.obs.as_mut() {
                o.func(f).on_cold(t, cold_lat);
            }
        } else {
            st.metrics.warm_starts += 1;
        }
        let e2e = cold_lat + inv.exec_s + self.cfg.network_latency_s;
        st.metrics.latency.add(e2e);

        // (5) Keep-alive decision at completion time.
        let gap = if self.cfg.provide_oracle_gap {
            if next_arrival_t.is_finite() {
                Some((next_arrival_t - completion).max(0.0))
            } else {
                None
            }
        } else {
            None
        };
        // During a carbon-feed outage the decision sees the stale-fallback
        // estimate (last known value extrapolated along the diurnal prior);
        // carbon *accounting* above always reads the true trace.
        let ci_now = match self.cfg.chaos.as_deref() {
            Some(ch) => match ch.stale_since(completion) {
                Some(outage_start) => {
                    st.metrics.chaos.stale_ci_decisions += 1;
                    if let Some(o) = self.obs.as_mut() {
                        o.func(f).on_stale();
                    }
                    ch.fallback_ci(self.ci, completion, outage_start)
                }
                None => self.ci.at(completion),
            },
            None => self.ci.at(completion),
        };
        let ctx = DecisionContext {
            t: completion,
            func: prof,
            ci: ci_now,
            reuse_probs: st.window.probs(),
            lambda_carbon: self.cfg.lambda_carbon,
            idle_power_w: idle_w,
            next_arrival_gap: gap,
        };
        let (action, keep_s) = {
            let (a, k) = policy.decide_seconds(&ctx);
            (a.min(KEEP_ALIVE_ACTIONS.len() - 1), k)
        };
        // A decision slower than the recovery timeout is discarded in favor
        // of the static fallback keep-alive. The policy still runs (its
        // internal state must match an undegraded replay); only the applied
        // action changes.
        let (action, keep_s) = match self.cfg.chaos.as_deref() {
            Some(ch) if ch.decision_degraded(completion) => {
                st.metrics.chaos.degraded_decisions += 1;
                if let Some(o) = self.obs.as_mut() {
                    o.func(f).on_degraded();
                }
                let a = ch.recovery().fallback_action.min(KEEP_ALIVE_ACTIONS.len() - 1);
                (a, KEEP_ALIVE_ACTIONS[a])
            }
            _ => (action, keep_s),
        };
        if let Some(o) = self.obs.as_mut() {
            o.func(f).on_decision(keep_s);
        }
        let pod = &mut st.pods[pod_idx];
        pod.busy_until = completion;
        pod.idle_start = completion;
        // Non-refreshing (static) policies arm the window once, when
        // the pod first idles; reuses do not extend it.
        if policy.refreshes_timer() || pod.warm_until == f64::INFINITY {
            pod.warm_until = completion + keep_s;
        }
        pod.pending = Some(Pending { action, t: completion });

        st.last_completion = completion;
        if completion > self.t_end {
            self.t_end = completion;
        }
        e2e
    }

    /// End-of-trace flush against the *global* `t_end` (across all shards,
    /// when sharded — the one cross-function coupling besides fold order).
    pub(crate) fn flush(&mut self, policy: &mut dyn KeepAlivePolicy, t_end: f64) {
        for (fi, st) in self.funcs.iter_mut().enumerate() {
            let f = self.f_lo + fi;
            let prof = &self.trace.functions[f];
            let idle_w =
                self.energy.lambda_idle * self.energy.active_power_w(prof.mem_mb, prof.cpu_cores);
            let FuncState { pods, metrics, .. } = st;
            for pod in pods.iter() {
                let horizon = pod.warm_until.min(t_end).max(pod.idle_start);
                let idle_carbon = idle_w
                    * self.ci.integrate(pod.idle_start, horizon)
                    / crate::energy::JOULES_PER_KWH;
                metrics.keepalive_carbon_g += idle_carbon;
                metrics.idle_pod_seconds += horizon - pod.idle_start;
                if let Some(o) = self.obs.as_mut() {
                    o.func(f).on_flush(horizon, idle_carbon);
                }
                if let Some(p) = pod.pending {
                    policy.observe(&Outcome {
                        func: f as u32,
                        action: p.action,
                        t: p.t,
                        resolved_t: horizon,
                        reused: false,
                        idle_span_s: horizon - pod.idle_start,
                        idle_carbon_g: idle_carbon,
                        cold_penalty_s: 0.0,
                        done: true,
                    });
                }
            }
        }
    }

    /// Fold this pass's per-function partial metrics into `into`, in
    /// ascending function-id order (the bit-identical merge contract).
    pub(crate) fn collect(&self, into: &mut SimMetrics) {
        for st in &self.funcs {
            into.merge(&st.metrics);
        }
    }

    /// Take this pass's telemetry partials (if collection was on). The
    /// caller folds shards into a [`SimObs`] in ascending shard order,
    /// mirroring `collect`.
    pub(crate) fn take_obs(&mut self) -> Option<ShardObs> {
        self.obs.take()
    }
}

impl<'a> Simulator<'a> {
    pub fn new(trace: &'a Trace, ci: &'a CarbonTrace, energy: EnergyModel, cfg: SimConfig) -> Self {
        Simulator { trace, ci, energy, cfg }
    }

    /// Run the policy over the whole trace.
    pub fn run(&self, policy: &mut dyn KeepAlivePolicy) -> SimResult {
        let trace = self.trace;
        let nf = trace.functions.len();
        let mut latencies = Vec::new();
        if self.cfg.track_latencies {
            latencies.reserve(trace.invocations.len());
        }
        let next_arrival = if self.cfg.provide_oracle_gap {
            next_arrival_times(trace)
        } else {
            Vec::new()
        };

        let mut pass = ShardPass::new(trace, self.ci, &self.energy, &self.cfg, 0..nf);
        for (idx, inv) in trace.invocations.iter().enumerate() {
            let na = if self.cfg.provide_oracle_gap {
                next_arrival[idx]
            } else {
                f64::INFINITY
            };
            let e2e = pass.step(policy, inv, na);
            if self.cfg.track_latencies {
                latencies.push(e2e);
            }
        }

        let t_end = pass.t_end;
        pass.flush(policy, t_end);
        let mut metrics = SimMetrics::new();
        pass.collect(&mut metrics);
        let obs = pass.take_obs().map(|shard| {
            let mut o = SimObs::new();
            o.absorb(shard);
            o
        });
        SimResult { metrics, latencies, obs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::fixed::FixedTimeout;
    use crate::trace::model::{FunctionProfile, Invocation, Runtime, TriggerType};

    fn one_fn_trace(arrivals: &[f64], cold_s: f64, exec_s: f64) -> Trace {
        Trace::new(
            vec![FunctionProfile {
                id: 0,
                runtime: Runtime::Python,
                trigger: TriggerType::Http,
                mem_mb: 100.0,
                cpu_cores: 1.0,
                cold_start_s: cold_s,
                mean_exec_s: exec_s,
            }],
            arrivals
                .iter()
                .map(|&t| Invocation { t, func: 0, exec_s })
                .collect(),
        )
    }

    fn sim<'a>(trace: &'a Trace, ci: &'a CarbonTrace) -> Simulator<'a> {
        Simulator::new(trace, ci, EnergyModel::default(), SimConfig::default())
    }

    #[test]
    fn all_cold_with_tiny_timeout() {
        // Arrivals 100s apart; even 60s keep-alive cannot bridge them.
        let trace = one_fn_trace(&[0.0, 100.0, 200.0], 1.0, 0.1);
        let ci = CarbonTrace::constant(300.0);
        let s = sim(&trace, &ci);
        let r = s.run(&mut FixedTimeout::huawei());
        assert_eq!(r.metrics.cold_starts, 3);
        assert_eq!(r.metrics.warm_starts, 0);
    }

    #[test]
    fn warm_after_first_with_large_timeout() {
        // Arrivals 10s apart; 60s keep-alive keeps the pod warm.
        let trace = one_fn_trace(&[0.0, 10.0, 20.0, 30.0], 1.0, 0.1);
        let ci = CarbonTrace::constant(300.0);
        let s = sim(&trace, &ci);
        let r = s.run(&mut FixedTimeout::huawei());
        assert_eq!(r.metrics.cold_starts, 1);
        assert_eq!(r.metrics.warm_starts, 3);
    }

    #[test]
    fn latency_includes_cold_exec_net() {
        let trace = one_fn_trace(&[0.0], 2.0, 0.5);
        let ci = CarbonTrace::constant(300.0);
        let s = sim(&trace, &ci);
        let r = s.run(&mut FixedTimeout::huawei());
        let want = 2.0 + 0.5 + crate::NETWORK_LATENCY_S;
        assert!((r.metrics.avg_latency_s() - want).abs() < 1e-12);
    }

    #[test]
    fn idle_carbon_charged_for_actual_idle_span() {
        // Two arrivals 10s apart (completion ~0.1 to arrival 10):
        // idle span ≈ 9.9s at idle power.
        let trace = one_fn_trace(&[0.0, 10.0], 0.0, 0.1);
        let ci = CarbonTrace::constant(360.0);
        let em = EnergyModel::default();
        let idle_w = em.lambda_idle * em.active_power_w(100.0, 1.0);
        let s = Simulator::new(&trace, &ci, em.clone(), SimConfig::default());
        let r = s.run(&mut FixedTimeout::huawei());
        // reuse idle [0.1, 10.0] = 9.9s + flush idle after second completion
        // capped at t_end (= last completion) so zero extra span.
        let want = idle_w * 9.9 * 360.0 / crate::energy::JOULES_PER_KWH;
        assert!(
            (r.metrics.keepalive_carbon_g - want).abs() < want * 1e-9,
            "got {} want {}",
            r.metrics.keepalive_carbon_g,
            want
        );
    }

    #[test]
    fn expired_pod_charged_full_timeout() {
        // Arrivals 200s apart; pod expires after 60s idle.
        let trace = one_fn_trace(&[0.0, 200.0], 0.0, 0.1);
        let ci = CarbonTrace::constant(360.0);
        let em = EnergyModel::default();
        let idle_w = em.lambda_idle * em.active_power_w(100.0, 1.0);
        let s = Simulator::new(&trace, &ci, em, SimConfig::default());
        let r = s.run(&mut FixedTimeout::huawei());
        // First pod idles the full 60s then expires; second completes at
        // t_end so flush adds nothing.
        let want = idle_w * 60.0 * 360.0 / crate::energy::JOULES_PER_KWH;
        assert!(
            (r.metrics.keepalive_carbon_g - want).abs() < want * 1e-9,
            "got {} want {}",
            r.metrics.keepalive_carbon_g,
            want
        );
        assert_eq!(r.metrics.cold_starts, 2);
        assert!((r.metrics.wasted_idle_seconds - 60.0).abs() < 1e-9);
    }

    #[test]
    fn concurrency_spawns_multiple_pods() {
        // Two arrivals at the same time need two pods.
        let trace = one_fn_trace(&[0.0, 0.0, 0.0], 0.5, 5.0);
        let ci = CarbonTrace::constant(300.0);
        let s = sim(&trace, &ci);
        let r = s.run(&mut FixedTimeout::huawei());
        assert_eq!(r.metrics.cold_starts, 3);
    }

    #[test]
    fn outcomes_reported_in_order() {
        struct Recorder {
            inner: FixedTimeout,
            outcomes: Vec<Outcome>,
            decides: usize,
        }
        impl KeepAlivePolicy for Recorder {
            fn name(&self) -> &str {
                "recorder"
            }
            fn decide(&mut self, ctx: &DecisionContext) -> usize {
                self.decides += 1;
                self.inner.decide(ctx)
            }
            fn observe(&mut self, o: &Outcome) {
                self.outcomes.push(*o);
            }
        }
        let trace = one_fn_trace(&[0.0, 10.0, 200.0], 0.0, 0.1);
        let ci = CarbonTrace::constant(300.0);
        let s = sim(&trace, &ci);
        let mut rec = Recorder {
            inner: FixedTimeout::new(60.0), // refreshing variant
            outcomes: Vec::new(),
            decides: 0,
        };
        s.run(&mut rec);
        assert_eq!(rec.decides, 3);
        assert_eq!(rec.outcomes.len(), 3);
        // First decision reused (10s gap < 60s), second expired with cold
        // penalty 0 (cold_start_s = 0 in this trace... use reused flags).
        assert!(rec.outcomes[0].reused);
        assert!(!rec.outcomes[1].reused);
        assert!((rec.outcomes[1].idle_span_s - 60.0).abs() < 1e-9);
        // Last resolved by flush:
        assert!(rec.outcomes[2].done);
    }

    #[test]
    fn huawei_static_window_not_refreshed() {
        // Arrivals every 25s; exec 0.1. A refreshing 60s timeout stays warm
        // forever; the Huawei static window (armed at first idle ≈0.1,
        // expires ≈60.1) goes cold at t=75 and re-arms.
        let arrivals: Vec<f64> = (0..8).map(|i| 25.0 * i as f64).collect();
        let trace = one_fn_trace(&arrivals, 1.0, 0.1);
        let ci = CarbonTrace::constant(300.0);
        let s = sim(&trace, &ci);
        let refresh = s.run(&mut FixedTimeout::new(60.0)).metrics;
        let stat = s.run(&mut FixedTimeout::huawei()).metrics;
        assert_eq!(refresh.cold_starts, 1);
        assert!(
            stat.cold_starts > refresh.cold_starts,
            "static window should go cold periodically: {} vs {}",
            stat.cold_starts,
            refresh.cold_starts
        );
    }

    #[test]
    fn latency_min_outlives_the_action_grid() {
        // Arrivals 120s apart exceed the 60s action cap but sit inside
        // Latency-Min's pre-warm horizon.
        let trace = one_fn_trace(&[0.0, 120.0, 240.0], 1.0, 0.1);
        let ci = CarbonTrace::constant(300.0);
        let s = sim(&trace, &ci);
        let r = s.run(&mut crate::policy::latency_min::LatencyMin).metrics;
        assert_eq!(r.cold_starts, 1);
        let r60 = s.run(&mut FixedTimeout::new(60.0)).metrics;
        assert_eq!(r60.cold_starts, 3);
    }

    #[test]
    fn cold_penalty_attributed_to_latest_expiry() {
        struct Cap(Vec<Outcome>);
        impl KeepAlivePolicy for Cap {
            fn name(&self) -> &str {
                "cap"
            }
            fn decide(&mut self, _: &DecisionContext) -> usize {
                0 // always 1s keep-alive
            }
            fn observe(&mut self, o: &Outcome) {
                self.0.push(*o);
            }
        }
        let trace = one_fn_trace(&[0.0, 100.0], 3.0, 0.1);
        let ci = CarbonTrace::constant(300.0);
        let s = sim(&trace, &ci);
        let mut cap = Cap(Vec::new());
        s.run(&mut cap);
        // First decision expires; second arrival is cold (cold_start 3s):
        let o = &cap.0[0];
        assert!(!o.reused);
        assert!((o.cold_penalty_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn tied_expiries_charge_exactly_one_cold_start() {
        // Two concurrent arrivals at t=0 spawn two pods; same exec and the
        // same 1 s keep-alive decision give them *tied* warm_until values.
        // Both expire before the arrival at t=100, which is therefore cold:
        // the 3 s penalty must be charged to exactly one of the two expired
        // decisions, not both.
        struct Cap(Vec<Outcome>);
        impl KeepAlivePolicy for Cap {
            fn name(&self) -> &str {
                "cap"
            }
            fn decide(&mut self, _: &DecisionContext) -> usize {
                0 // always 1s keep-alive
            }
            fn observe(&mut self, o: &Outcome) {
                self.0.push(*o);
            }
        }
        let trace = one_fn_trace(&[0.0, 0.0, 100.0], 3.0, 0.1);
        let ci = CarbonTrace::constant(300.0);
        let s = sim(&trace, &ci);
        let mut cap = Cap(Vec::new());
        s.run(&mut cap);
        let expired: Vec<&Outcome> =
            cap.0.iter().filter(|o| !o.reused && !o.done).collect();
        assert_eq!(expired.len(), 2);
        let charged: Vec<&&Outcome> =
            expired.iter().filter(|o| o.cold_penalty_s > 0.0).collect();
        assert_eq!(charged.len(), 1, "exactly one tied expiry takes the penalty");
        assert!((charged[0].cold_penalty_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn oracle_gap_populated_when_enabled() {
        struct GapCheck(Vec<Option<f64>>);
        impl KeepAlivePolicy for GapCheck {
            fn name(&self) -> &str {
                "gapcheck"
            }
            fn decide(&mut self, ctx: &DecisionContext) -> usize {
                self.0.push(ctx.next_arrival_gap);
                4
            }
        }
        let trace = one_fn_trace(&[0.0, 50.0], 0.0, 1.0);
        let ci = CarbonTrace::constant(300.0);
        let mut cfg = SimConfig::default();
        cfg.provide_oracle_gap = true;
        let s = Simulator::new(&trace, &ci, EnergyModel::default(), cfg);
        let mut gc = GapCheck(Vec::new());
        s.run(&mut gc);
        // First decision at completion=1.0, next arrival 50 -> gap 49.
        assert!((gc.0[0].unwrap() - 49.0).abs() < 1e-9);
        // Last invocation has no successor.
        assert!(gc.0[1].is_none());
    }

    #[test]
    fn deterministic_metrics() {
        let trace = crate::trace::synth::TraceGenerator::new(
            crate::trace::synth::SynthConfig::small(3),
        )
        .generate();
        let ci = CarbonTrace::constant(300.0);
        let s = sim(&trace, &ci);
        let a = s.run(&mut FixedTimeout::huawei());
        let b = s.run(&mut FixedTimeout::huawei());
        assert_eq!(a.metrics.cold_starts, b.metrics.cold_starts);
        assert!((a.metrics.total_carbon_g() - b.metrics.total_carbon_g()).abs() < 1e-12);
    }
}
