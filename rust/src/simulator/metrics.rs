//! Simulation metrics (paper §IV-A6) including the composite LCP and IRI.

use crate::util::stats::Running;

/// Aggregated outcome of one simulation run.
#[derive(Debug, Clone, Default)]
pub struct SimMetrics {
    pub invocations: u64,
    pub cold_starts: u64,
    pub warm_starts: u64,
    /// End-to-end latency accumulator (cold + exec + network), seconds.
    pub latency: Running,
    /// Keep-alive (idle) carbon, grams CO₂.
    pub keepalive_carbon_g: f64,
    /// Execution carbon, grams CO₂.
    pub exec_carbon_g: f64,
    /// Cold-start carbon, grams CO₂.
    pub cold_carbon_g: f64,
    /// Sum of cold-start latencies incurred (s) — the C_cold side of the
    /// blended objective.
    pub cold_latency_s: f64,
    /// Total idle pod-seconds retained.
    pub idle_pod_seconds: f64,
    /// Total wasted idle pod-seconds (idle periods that ended in expiry).
    pub wasted_idle_seconds: f64,
    /// Degraded-mode event counts under fault injection (all zero without
    /// an injector — `SimConfig::chaos`).
    pub chaos: crate::chaos::ChaosCounters,
}

impl SimMetrics {
    pub fn new() -> Self {
        SimMetrics { latency: Running::new(), ..Default::default() }
    }

    /// Fold another run's (or function's) metrics into this one. All f64
    /// fields are plain sums, so the fold order determines the result bits:
    /// merging per-function partials in function-id order reproduces a
    /// sequential per-function accumulation exactly — the reduction the
    /// sharded simulator relies on for bit-identical results
    /// (`simulator::sharded`).
    pub fn merge(&mut self, other: &SimMetrics) {
        self.invocations += other.invocations;
        self.cold_starts += other.cold_starts;
        self.warm_starts += other.warm_starts;
        self.latency.merge(&other.latency);
        self.keepalive_carbon_g += other.keepalive_carbon_g;
        self.exec_carbon_g += other.exec_carbon_g;
        self.cold_carbon_g += other.cold_carbon_g;
        self.cold_latency_s += other.cold_latency_s;
        self.idle_pod_seconds += other.idle_pod_seconds;
        self.wasted_idle_seconds += other.wasted_idle_seconds;
        self.chaos.merge(&other.chaos);
    }

    /// Cold-start rate in [0,1].
    pub fn cold_rate(&self) -> f64 {
        if self.invocations == 0 {
            0.0
        } else {
            self.cold_starts as f64 / self.invocations as f64
        }
    }

    /// Mean end-to-end latency (s).
    pub fn avg_latency_s(&self) -> f64 {
        self.latency.mean()
    }

    /// Total carbon: execution + keep-alive + cold (paper §II-B).
    pub fn total_carbon_g(&self) -> f64 {
        self.exec_carbon_g + self.keepalive_carbon_g + self.cold_carbon_g
    }

    /// Latency–Carbon Product: avg E2E latency × total carbon
    /// (lower is better; §IV-A6).
    pub fn lcp(&self) -> f64 {
        self.avg_latency_s() * self.total_carbon_g()
    }

    /// Idle Reuse Inefficiency: cold-start count × keep-alive carbon
    /// (lower is better; §IV-A6).
    pub fn iri(&self) -> f64 {
        self.cold_starts as f64 * self.keepalive_carbon_g
    }

    /// One human-readable summary line (experiment harness output).
    pub fn summary_row(&self, label: &str) -> String {
        format!(
            "{label:<14} cold={:<8} latency={:.4}s keepalive={:.3}g total={:.3}g LCP={:.2} IRI={:.0}",
            self.cold_starts,
            self.avg_latency_s(),
            self.keepalive_carbon_g,
            self.total_carbon_g(),
            self.lcp(),
            self.iri(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SimMetrics {
        let mut m = SimMetrics::new();
        m.invocations = 100;
        m.cold_starts = 20;
        m.warm_starts = 80;
        for _ in 0..100 {
            m.latency.add(0.5);
        }
        m.keepalive_carbon_g = 10.0;
        m.exec_carbon_g = 30.0;
        m.cold_carbon_g = 5.0;
        m
    }

    #[test]
    fn composites() {
        let m = sample();
        assert!((m.cold_rate() - 0.2).abs() < 1e-12);
        assert!((m.avg_latency_s() - 0.5).abs() < 1e-12);
        assert!((m.total_carbon_g() - 45.0).abs() < 1e-12);
        assert!((m.lcp() - 22.5).abs() < 1e-12);
        assert!((m.iri() - 200.0).abs() < 1e-12);
    }

    #[test]
    fn merge_sums_fields_and_latency() {
        let mut a = sample();
        let b = sample();
        a.merge(&b);
        assert_eq!(a.invocations, 200);
        assert_eq!(a.cold_starts, 40);
        assert_eq!(a.latency.count, 200);
        assert!((a.avg_latency_s() - 0.5).abs() < 1e-12);
        assert!((a.keepalive_carbon_g - 20.0).abs() < 1e-12);
        assert!((a.total_carbon_g() - 90.0).abs() < 1e-12);
        // Merging empty metrics changes nothing.
        let before = a.clone();
        a.merge(&SimMetrics::new());
        assert_eq!(a.keepalive_carbon_g.to_bits(), before.keepalive_carbon_g.to_bits());
        assert_eq!(a.latency.sum.to_bits(), before.latency.sum.to_bits());
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = SimMetrics::new();
        assert_eq!(m.cold_rate(), 0.0);
        assert_eq!(m.avg_latency_s(), 0.0);
        assert_eq!(m.lcp(), 0.0);
    }
}
