//! Trace-driven serverless cluster simulator (paper §III-A component 4).
//!
//! Replays a [`crate::trace::Trace`] against a keep-alive policy: per-
//! function warm pools, cold/warm start accounting, CI-integrated idle
//! carbon, and realized-outcome feedback for RL training.

pub mod engine;
pub mod metrics;
pub mod parallel;
pub mod pod;
pub mod reuse;
pub mod sharded;

pub use engine::{SimConfig, SimResult, Simulator};
pub use metrics::SimMetrics;
pub use parallel::{BoxedPolicy, SweepCell, SweepOutcome, SweepRunner};
pub use sharded::ShardedSimulator;
