//! Parallel simulation-sweep harness (EXPERIMENTS.md §Perf iteration 2).
//!
//! The paper's evaluation (§IV) sweeps six policies × multiple λ_carbon
//! points × seeds over ≈1M-invocation traces — embarrassingly parallel
//! across configurations, exactly the shape dslab-faas exploits for
//! serverless simulation. [`SweepRunner`] fans a list of [`SweepCell`]s
//! (policy factory + [`SimConfig`], with optional per-cell trace / CI /
//! energy-model overrides) across a scoped std thread pool and returns
//! results in **deterministic cell order**.
//!
//! Determinism: every cell gets a *fresh* policy from its factory and runs
//! a fully independent [`Simulator`] over shared immutable inputs, so each
//! cell's [`SimMetrics`](crate::simulator::SimMetrics) are bit-identical to
//! a sequential `Simulator::run` of the same cell — thread scheduling can
//! reorder *execution*, never *results* (asserted by
//! `rust/tests/property_parallel.rs`). No new dependencies: work stealing
//! is an atomic cursor over the cell list, `std::thread::scope` keeps the
//! borrows lifetimes-clean.
//!
//! When a sweep has fewer cells than threads, the leftover cores are
//! granted to intra-cell *function sharding* (see
//! [`crate::simulator::sharded`]): each cell runs under a
//! [`ShardedSimulator`] with `threads / workers` shards, so a 2-cell sweep
//! on a 16-core box still uses the machine. Floor division guarantees
//! `workers × intra ≤ threads` (no oversubscription), and sharded replay
//! is bit-identical to sequential, so sweep results are unchanged.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::carbon::intensity::CarbonTrace;
use crate::energy::model::EnergyModel;
use crate::simulator::engine::{SimConfig, SimResult};
use crate::simulator::sharded::ShardedSimulator;
use crate::trace::model::Trace;

pub use crate::policy::BoxedPolicy;

/// Builds a fresh policy instance for one sweep cell. Called exactly once
/// per cell, on the worker thread that executes it — stateful policies
/// (LACE-RL reuse windows, DPSO swarms, recorders) never leak state across
/// cells.
pub type PolicyFactory<'a> = Box<dyn Fn() -> BoxedPolicy + Send + Sync + 'a>;

/// One sweep cell: a policy factory plus its simulation config, with
/// optional overrides of the runner's shared trace / CI / energy model
/// (used by the ablation and Table III sweeps).
pub struct SweepCell<'a> {
    pub label: String,
    pub cfg: SimConfig,
    pub factory: PolicyFactory<'a>,
    pub trace: Option<&'a Trace>,
    pub ci: Option<&'a CarbonTrace>,
    pub energy: Option<EnergyModel>,
}

impl<'a> SweepCell<'a> {
    pub fn new(
        label: impl Into<String>,
        cfg: SimConfig,
        factory: impl Fn() -> BoxedPolicy + Send + Sync + 'a,
    ) -> Self {
        SweepCell {
            label: label.into(),
            cfg,
            factory: Box::new(factory),
            trace: None,
            ci: None,
            energy: None,
        }
    }

    /// Run this cell on its own trace (Table III's per-case slices).
    pub fn with_trace(mut self, trace: &'a Trace) -> Self {
        self.trace = Some(trace);
        self
    }

    /// Run this cell against a different CI trace (carbon-blind ablation).
    pub fn with_ci(mut self, ci: &'a CarbonTrace) -> Self {
        self.ci = Some(ci);
        self
    }

    /// Run this cell under a different energy model (λ_idle sweep).
    pub fn with_energy(mut self, energy: EnergyModel) -> Self {
        self.energy = Some(energy);
        self
    }
}

/// One cell's result, in the cell's original list position.
pub struct SweepOutcome {
    pub label: String,
    pub result: SimResult,
    /// The policy after the run — lets callers recover trained/recorded
    /// state (e.g. the cost experiment's context collector).
    pub policy: BoxedPolicy,
}

/// Executes sweep cells across a scoped thread pool.
///
/// ```ignore
/// // (doctests don't inherit the xla rpath link flags; the unit tests
/// // below exercise this exact shape)
/// # use lace_rl::simulator::parallel::{SweepCell, SweepRunner};
/// # use lace_rl::simulator::SimConfig;
/// # use lace_rl::policy::FixedTimeout;
/// # use lace_rl::energy::model::EnergyModel;
/// # let (trace, ci) = unimplemented!();
/// let runner = SweepRunner::new(&trace, &ci, EnergyModel::default());
/// let cells = vec![
///     SweepCell::new("huawei-60s", SimConfig::default(), || {
///         Box::new(FixedTimeout::huawei())
///     }),
/// ];
/// let outcomes = runner.run(cells); // same order as `cells`
/// ```
pub struct SweepRunner<'a> {
    trace: &'a Trace,
    ci: &'a CarbonTrace,
    energy: EnergyModel,
    threads: usize,
}

impl<'a> SweepRunner<'a> {
    /// A runner over shared inputs, sized to the machine
    /// (`std::thread::available_parallelism`). Override with
    /// [`with_threads`](Self::with_threads) or the `LACE_SWEEP_THREADS`
    /// env var (`LACE_SWEEP_THREADS=1` forces sequential execution for
    /// debugging/CI determinism triage).
    pub fn new(trace: &'a Trace, ci: &'a CarbonTrace, energy: EnergyModel) -> Self {
        let threads = std::env::var("LACE_SWEEP_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        SweepRunner { trace, ci, energy, threads }
    }

    /// Pin the worker count (clamped to ≥ 1).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute every cell; results come back in the cells' original order
    /// regardless of which worker finished when.
    pub fn run(&self, cells: Vec<SweepCell<'a>>) -> Vec<SweepOutcome> {
        let n = cells.len();
        if n == 0 {
            return Vec::new();
        }
        let slots: Vec<Mutex<Option<SweepOutcome>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let cursor = AtomicUsize::new(0);
        let workers = self.threads.min(n);
        // When there are fewer cells than threads, grant the leftover cores
        // to intra-cell function sharding (oversubscription guard: floor
        // division keeps workers × intra ≤ threads). Sharded replay is
        // bit-identical to sequential, so results don't depend on `intra`.
        let intra = (self.threads / workers).max(1);
        let cells = &cells;
        let slots_ref = &slots;
        let cursor_ref = &cursor;

        let work = move || loop {
            let i = cursor_ref.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let cell = &cells[i];
            let mut policy = (cell.factory)();
            let sim = ShardedSimulator::new(
                cell.trace.unwrap_or(self.trace),
                cell.ci.unwrap_or(self.ci),
                cell.energy.clone().unwrap_or_else(|| self.energy.clone()),
                cell.cfg.clone(),
            )
            .with_shards(intra);
            let result = sim.run(policy.as_mut());
            *slots_ref[i].lock().unwrap() =
                Some(SweepOutcome { label: cell.label.clone(), result, policy });
        };

        if workers == 1 {
            // Inline — no thread overhead for single-cell/forced-sequential
            // sweeps, same code path as the workers.
            work();
        } else {
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(work.clone());
                }
            });
        }

        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("every sweep cell executes"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::fixed::FixedTimeout;
    use crate::policy::{CarbonMin, LatencyMin};
    use crate::trace::synth::{SynthConfig, TraceGenerator};

    fn small_trace(seed: u64) -> Trace {
        TraceGenerator::new(SynthConfig::small(seed)).generate()
    }

    fn fixed_cells<'a>(n: usize) -> Vec<SweepCell<'a>> {
        (0..n)
            .map(|i| {
                let secs = 1.0 + i as f64 * 7.0;
                SweepCell::new(format!("fixed-{secs}"), SimConfig::default(), move || {
                    Box::new(FixedTimeout::new(secs)) as BoxedPolicy
                })
            })
            .collect()
    }

    #[test]
    fn results_keep_cell_order() {
        let trace = small_trace(1);
        let ci = CarbonTrace::constant(300.0);
        let runner = SweepRunner::new(&trace, &ci, EnergyModel::default()).with_threads(4);
        let outcomes = runner.run(fixed_cells(9));
        assert_eq!(outcomes.len(), 9);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.label, format!("fixed-{}", 1.0 + i as f64 * 7.0));
            assert_eq!(o.result.metrics.invocations as usize, trace.len());
        }
    }

    #[test]
    fn parallel_matches_single_thread_bitwise() {
        let trace = small_trace(2);
        let ci = CarbonTrace::constant(300.0);
        let seq = SweepRunner::new(&trace, &ci, EnergyModel::default()).with_threads(1);
        let par = SweepRunner::new(&trace, &ci, EnergyModel::default()).with_threads(8);
        let a = seq.run(fixed_cells(6));
        let b = par.run(fixed_cells(6));
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.result.metrics.cold_starts, y.result.metrics.cold_starts);
            // Bit-identical, not approximately equal:
            assert_eq!(
                x.result.metrics.keepalive_carbon_g.to_bits(),
                y.result.metrics.keepalive_carbon_g.to_bits()
            );
            assert_eq!(
                x.result.metrics.total_carbon_g().to_bits(),
                y.result.metrics.total_carbon_g().to_bits()
            );
        }
    }

    #[test]
    fn per_cell_overrides_apply() {
        let trace = small_trace(3);
        let short = Trace::new(
            trace.functions.clone(),
            trace.invocations.iter().take(10).copied().collect(),
        );
        let ci = CarbonTrace::constant(300.0);
        let flat = CarbonTrace::constant(600.0);
        let runner = SweepRunner::new(&trace, &ci, EnergyModel::default()).with_threads(2);
        let cells = vec![
            SweepCell::new("base", SimConfig::default(), || {
                Box::new(FixedTimeout::huawei()) as BoxedPolicy
            }),
            SweepCell::new("short-trace", SimConfig::default(), || {
                Box::new(FixedTimeout::huawei()) as BoxedPolicy
            })
            .with_trace(&short),
            SweepCell::new("double-ci", SimConfig::default(), || {
                Box::new(FixedTimeout::huawei()) as BoxedPolicy
            })
            .with_ci(&flat),
            SweepCell::new("hot-idle", SimConfig::default(), || {
                Box::new(FixedTimeout::huawei()) as BoxedPolicy
            })
            .with_energy(EnergyModel::with_lambda_idle(0.8)),
        ];
        let o = runner.run(cells);
        assert_eq!(o[0].result.metrics.invocations as usize, trace.len());
        assert_eq!(o[1].result.metrics.invocations, 10);
        // Doubling CI doubles keep-alive carbon; 4× λ_idle quadruples it.
        let base = o[0].result.metrics.keepalive_carbon_g;
        assert!((o[2].result.metrics.keepalive_carbon_g / base - 2.0).abs() < 1e-9);
        assert!((o[3].result.metrics.keepalive_carbon_g / base - 4.0).abs() < 1e-9);
    }

    #[test]
    fn stateful_policy_returned_in_outcome() {
        let trace = small_trace(4);
        let ci = CarbonTrace::constant(300.0);
        let runner = SweepRunner::new(&trace, &ci, EnergyModel::default()).with_threads(2);
        let cells = vec![
            SweepCell::new("lat", SimConfig::default(), || Box::new(LatencyMin) as BoxedPolicy),
            SweepCell::new("car", SimConfig::default(), || Box::new(CarbonMin) as BoxedPolicy),
        ];
        let o = runner.run(cells);
        assert_eq!(o[0].policy.name(), "latency-min");
        assert_eq!(o[1].policy.name(), "carbon-min");
    }

    #[test]
    fn empty_sweep_is_empty() {
        let trace = small_trace(5);
        let ci = CarbonTrace::constant(300.0);
        let runner = SweepRunner::new(&trace, &ci, EnergyModel::default());
        assert!(runner.run(Vec::new()).is_empty());
    }
}
