//! Pod state for the per-function warm pool.

/// A pending keep-alive decision awaiting its realized outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Pending {
    /// Chosen action (index into [`crate::KEEP_ALIVE_ACTIONS`]).
    pub action: usize,
    /// Decision (pod completion) time.
    pub t: f64,
}

/// One container instance. Lifecycle: created on a cold start, `busy` while
/// executing, then idle-warm until `warm_until` (set by the policy) or the
/// next reuse, whichever comes first.
#[derive(Debug, Clone)]
pub struct Pod {
    /// Executing until this time; only available for reuse afterwards.
    pub busy_until: f64,
    /// Warm (reusable) until this time; meaningless while busy.
    pub warm_until: f64,
    /// When the current idle period started (= last completion time).
    pub idle_start: f64,
    /// Unresolved keep-alive decision for the current idle period.
    pub pending: Option<Pending>,
}

impl Pod {
    /// A pod that just started executing (cold start at `t`, finishing at
    /// `completion`).
    pub fn new_busy(completion: f64) -> Pod {
        Pod {
            busy_until: completion,
            warm_until: f64::INFINITY, // set by the keep-alive decision
            idle_start: completion,
            pending: None,
        }
    }

    /// Available to serve an arrival at time `t`?
    #[inline]
    pub fn available(&self, t: f64) -> bool {
        self.busy_until <= t && self.warm_until > t
    }

    /// Expired (idle period over) as of time `t`?
    #[inline]
    pub fn expired(&self, t: f64) -> bool {
        self.busy_until <= t && self.warm_until <= t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_states() {
        let mut p = Pod::new_busy(10.0);
        assert!(!p.available(5.0)); // busy
        assert!(!p.expired(5.0));
        // Completion + keep-alive decision of 30s:
        p.warm_until = 40.0;
        p.idle_start = 10.0;
        assert!(p.available(10.0));
        assert!(p.available(39.9));
        assert!(!p.available(40.0));
        assert!(p.expired(40.0));
    }

    #[test]
    fn busy_pod_never_expired() {
        let p = Pod::new_busy(10.0);
        assert!(!p.expired(5.0));
    }
}
