//! Per-function sliding reuse-interval window (paper §III-A: "reuse
//! probability p_k of its pod estimated using a historical window W over
//! different keep-alive durations k").
//!
//! Tracks the last `W` observed idle gaps (completion → next arrival) per
//! function; `probs` answers P[gap ≤ k] for each keep-alive candidate.
//! Ring-buffer storage, O(W) probability evaluation with W = 64 — this is
//! on the per-invocation hot path.

use crate::KEEP_ALIVE_ACTIONS;

/// Default window length (recent gaps remembered per function).
pub const DEFAULT_WINDOW: usize = 64;

/// Sliding window of reuse gaps for one function.
///
/// Per-action ≤-counts are maintained *incrementally* on push (O(5) per
/// update, O(5) per `probs` query) rather than rescanned (O(5·W)): `probs`
/// runs once per invocation on the decision hot path, and the incremental
/// form took the simulator's LACE-RL end-to-end run from 0.42 to ≈0.5M
/// invocations/s (EXPERIMENTS.md §Perf iteration 1).
#[derive(Debug, Clone)]
pub struct ReuseWindow {
    gaps: Vec<f64>,
    head: usize,
    len: usize,
    /// counts[a] = #{gap in window : gap ≤ KEEP_ALIVE_ACTIONS[a]}.
    counts: [u32; 5],
}

impl ReuseWindow {
    pub fn new(capacity: usize) -> Self {
        ReuseWindow {
            gaps: vec![0.0; capacity.max(1)],
            head: 0,
            len: 0,
            counts: [0; 5],
        }
    }

    #[inline]
    fn bump(counts: &mut [u32; 5], gap: f64, delta: i32) {
        for (ai, &k) in KEEP_ALIVE_ACTIONS.iter().enumerate() {
            if gap <= k {
                counts[ai] = counts[ai].wrapping_add_signed(delta);
            }
        }
    }

    /// Record an observed idle gap (seconds).
    #[inline]
    pub fn push(&mut self, gap: f64) {
        let cap = self.gaps.len();
        if self.len == cap {
            // Evict the slot we're about to overwrite.
            Self::bump(&mut self.counts, self.gaps[self.head], -1);
        } else {
            self.len += 1;
        }
        self.gaps[self.head] = gap;
        self.head = (self.head + 1) % cap;
        Self::bump(&mut self.counts, gap, 1);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// P[gap ≤ k] for each keep-alive action. With no history returns the
    /// uninformed prior 0.5 for every action (cold-start-agnostic).
    #[inline]
    pub fn probs(&self) -> [f64; 5] {
        if self.len == 0 {
            return [0.5; 5];
        }
        let n = self.len as f64;
        let mut out = [0.0; 5];
        for (o, c) in out.iter_mut().zip(self.counts.iter()) {
            *o = *c as f64 / n;
        }
        out
    }

    /// Mean recorded gap (None when empty).
    pub fn mean_gap(&self) -> Option<f64> {
        if self.len == 0 {
            return None;
        }
        Some(self.gaps[..self.len].iter().sum::<f64>() / self.len as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_window_uninformed() {
        let w = ReuseWindow::new(8);
        assert_eq!(w.probs(), [0.5; 5]);
        assert_eq!(w.mean_gap(), None);
    }

    #[test]
    fn probs_monotone_in_k() {
        let mut w = ReuseWindow::new(16);
        for g in [0.5, 3.0, 8.0, 20.0, 100.0] {
            w.push(g);
        }
        let p = w.probs();
        for i in 1..5 {
            assert!(p[i] >= p[i - 1], "{p:?}");
        }
        // k=1 covers only the 0.5 gap; k=60 covers all but 100.
        assert!((p[0] - 0.2).abs() < 1e-12);
        assert!((p[4] - 0.8).abs() < 1e-12);
    }

    #[test]
    fn ring_evicts_oldest() {
        let mut w = ReuseWindow::new(4);
        for g in [100.0, 100.0, 100.0, 100.0] {
            w.push(g);
        }
        assert_eq!(w.probs()[4], 0.0); // nothing within 60s
        for g in [1.0, 1.0, 1.0, 1.0] {
            w.push(g);
        }
        assert_eq!(w.len(), 4);
        assert_eq!(w.probs()[4], 1.0); // old gaps fully evicted
    }

    #[test]
    fn mean_gap() {
        let mut w = ReuseWindow::new(8);
        w.push(2.0);
        w.push(4.0);
        assert_eq!(w.mean_gap(), Some(3.0));
    }

    #[test]
    fn incremental_counts_match_rescan() {
        // Cross-check the O(1) counters against a brute-force rescan under
        // heavy eviction churn.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(42);
        let mut w = ReuseWindow::new(16);
        for _ in 0..500 {
            w.push(rng.lognormal(1.5, 1.5));
            let got = w.probs();
            // brute force over the live window
            let live = &w.gaps[..w.len];
            for (ai, &k) in KEEP_ALIVE_ACTIONS.iter().enumerate() {
                let want =
                    live.iter().filter(|&&g| g <= k).count() as f64 / w.len as f64;
                assert!((got[ai] - want).abs() < 1e-12, "action {ai}");
            }
        }
    }
}
