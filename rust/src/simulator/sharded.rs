//! Function-sharded parallel replay of a single simulation run.
//!
//! `simulator::parallel::SweepRunner` parallelizes *across* runs; this
//! module parallelizes *within* one. The trace's functions are partitioned
//! into K contiguous-by-id shards (`Trace::shard_index`, built once and
//! cached), each shard replays its arrival-ordered sub-stream on its own
//! scoped thread against a policy instance from `KeepAlivePolicy::fork`,
//! and the per-shard results are merged deterministically:
//!
//! * metrics fold per-function partials in ascending function-id order
//!   (contiguous shard ranges concatenate to exactly the sequential fold);
//! * the end-of-trace flush runs serially against the global `t_end`
//!   (max over shards);
//! * tracked latencies scatter back to global arrival order through the
//!   invocation indices stored in the shard index.
//!
//! Result: bit-identical output to [`Simulator::run`] for every policy
//! that forks (property-tested in `rust/tests/property_sharded.rs`).
//! Policies that return `None` from `fork` — and traces with fewer than two
//! functions — fall back to the sequential path transparently.

use crate::carbon::intensity::CarbonTrace;
use crate::energy::model::EnergyModel;
use crate::policy::{BoxedPolicy, KeepAlivePolicy};
use crate::simulator::engine::{next_arrival_times, ShardPass, SimConfig, SimResult, Simulator};
use crate::simulator::metrics::SimMetrics;
use crate::trace::model::Trace;

/// Environment override for the shard count (`0`/`1` force sequential).
pub const SHARDS_ENV: &str = "LACE_SIM_SHARDS";

/// A single-run simulator that replays disjoint function shards in
/// parallel. Drop-in for [`Simulator`]: same inputs, bit-identical output.
pub struct ShardedSimulator<'a> {
    pub trace: &'a Trace,
    pub ci: &'a CarbonTrace,
    pub energy: EnergyModel,
    pub cfg: SimConfig,
    shards: usize,
}

impl<'a> ShardedSimulator<'a> {
    /// Shard count from `LACE_SIM_SHARDS`, else available parallelism.
    pub fn new(trace: &'a Trace, ci: &'a CarbonTrace, energy: EnergyModel, cfg: SimConfig) -> Self {
        let shards = std::env::var(SHARDS_ENV)
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
            .max(1);
        ShardedSimulator { trace, ci, energy, cfg, shards }
    }

    /// Fix the shard count explicitly (clamped to at least 1).
    pub fn with_shards(mut self, k: usize) -> Self {
        self.shards = k.max(1);
        self
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    fn run_sequential(&self, policy: &mut dyn KeepAlivePolicy) -> SimResult {
        Simulator::new(self.trace, self.ci, self.energy.clone(), self.cfg.clone()).run(policy)
    }

    /// Run the policy over the whole trace, sharded across threads when the
    /// policy forks and more than one shard is useful.
    pub fn run(&self, policy: &mut dyn KeepAlivePolicy) -> SimResult {
        let trace = self.trace;
        let nf = trace.functions.len();
        let k = self.shards.min(nf).max(1);
        if k <= 1 || trace.is_empty() {
            return self.run_sequential(policy);
        }
        // All-or-nothing fork: a policy that cannot shard keeps the
        // sequential semantics it asked for.
        let mut forks: Vec<BoxedPolicy> = Vec::with_capacity(k);
        for _ in 0..k {
            match policy.fork() {
                Some(f) => forks.push(f),
                None => return self.run_sequential(policy),
            }
        }

        let index = trace.shard_index(k);
        let next_arrival = if self.cfg.provide_oracle_gap {
            next_arrival_times(trace)
        } else {
            Vec::new()
        };
        let ci = self.ci;
        let energy = &self.energy;
        let cfg = &self.cfg;
        let index_ref = &*index;
        let next_arrival_ref = &next_arrival;

        // Phase 1: parallel main pass, one thread per shard.
        let mut results: Vec<(ShardPass<'_>, Vec<f64>, BoxedPolicy)> =
            std::thread::scope(|s| {
                let handles: Vec<_> = forks
                    .into_iter()
                    .enumerate()
                    .map(|(si, mut fork)| {
                        s.spawn(move || {
                            let mut pass = ShardPass::new(
                                trace,
                                ci,
                                energy,
                                cfg,
                                index_ref.func_ranges[si].clone(),
                            );
                            let list = &index_ref.invocations[si];
                            let mut lats = if cfg.track_latencies {
                                Vec::with_capacity(list.len())
                            } else {
                                Vec::new()
                            };
                            for &gi in list {
                                let na = if cfg.provide_oracle_gap {
                                    next_arrival_ref[gi as usize]
                                } else {
                                    f64::INFINITY
                                };
                                let e2e = pass.step(
                                    fork.as_mut(),
                                    &trace.invocations[gi as usize],
                                    na,
                                );
                                if cfg.track_latencies {
                                    lats.push(e2e);
                                }
                            }
                            (pass, lats, fork)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });

        // Phase 2: serial merge in shard (= function-id) order. The flush
        // needs the global t_end, so it cannot run inside the shards.
        let t_end = results.iter().fold(0.0f64, |acc, (p, _, _)| acc.max(p.t_end));
        let mut metrics = SimMetrics::new();
        let mut obs: Option<crate::obs::SimObs> = None;
        let mut latencies = if self.cfg.track_latencies {
            vec![0.0; trace.invocations.len()]
        } else {
            Vec::new()
        };
        for (si, (pass, lats, fork)) in results.iter_mut().enumerate() {
            pass.flush(fork.as_mut(), t_end);
            pass.collect(&mut metrics);
            // Telemetry folds in the same shard (= function-id) order as
            // the metrics, so merged obs output is shard-count-invariant.
            if let Some(shard) = pass.take_obs() {
                obs.get_or_insert_with(crate::obs::SimObs::new).absorb(shard);
            }
            if self.cfg.track_latencies {
                for (&gi, &l) in index.invocations[si].iter().zip(lats.iter()) {
                    latencies[gi as usize] = l;
                }
            }
            policy.absorb(fork.as_mut());
        }
        SimResult { metrics, latencies, obs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::fixed::FixedTimeout;
    use crate::trace::synth::{SynthConfig, TraceGenerator};

    fn mk(seed: u64) -> Trace {
        TraceGenerator::new(SynthConfig::small(seed)).generate()
    }

    #[test]
    fn sharded_matches_sequential_fixed_policy() {
        let trace = mk(5);
        let ci = CarbonTrace::constant(320.0);
        let cfg = SimConfig { track_latencies: true, ..SimConfig::default() };
        let seq = Simulator::new(&trace, &ci, EnergyModel::default(), cfg.clone())
            .run(&mut FixedTimeout::huawei());
        for k in [1, 2, 3] {
            let sh = ShardedSimulator::new(&trace, &ci, EnergyModel::default(), cfg.clone())
                .with_shards(k)
                .run(&mut FixedTimeout::huawei());
            assert_eq!(seq.metrics.cold_starts, sh.metrics.cold_starts, "k={k}");
            assert_eq!(
                seq.metrics.keepalive_carbon_g.to_bits(),
                sh.metrics.keepalive_carbon_g.to_bits(),
                "k={k}"
            );
            assert_eq!(seq.latencies.len(), sh.latencies.len());
            for (a, b) in seq.latencies.iter().zip(sh.latencies.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "k={k}");
            }
        }
    }

    #[test]
    fn non_forkable_policy_falls_back() {
        struct NoFork;
        impl KeepAlivePolicy for NoFork {
            fn name(&self) -> &str {
                "no-fork"
            }
            fn decide(&mut self, _: &crate::policy::DecisionContext) -> usize {
                0
            }
        }
        let trace = mk(6);
        let ci = CarbonTrace::constant(320.0);
        let seq = Simulator::new(&trace, &ci, EnergyModel::default(), SimConfig::default())
            .run(&mut NoFork);
        let sh = ShardedSimulator::new(&trace, &ci, EnergyModel::default(), SimConfig::default())
            .with_shards(4)
            .run(&mut NoFork);
        assert_eq!(seq.metrics.cold_starts, sh.metrics.cold_starts);
        assert_eq!(
            seq.metrics.total_carbon_g().to_bits(),
            sh.metrics.total_carbon_g().to_bits()
        );
    }

    #[test]
    fn more_shards_than_functions_clamps() {
        let trace = mk(7);
        let ci = CarbonTrace::constant(320.0);
        let sim = ShardedSimulator::new(&trace, &ci, EnergyModel::default(), SimConfig::default())
            .with_shards(10_000);
        let r = sim.run(&mut FixedTimeout::huawei());
        assert_eq!(r.metrics.invocations as usize, trace.len());
    }

    #[test]
    fn empty_trace_is_fine() {
        let trace = Trace::default();
        let ci = CarbonTrace::constant(320.0);
        let r = ShardedSimulator::new(&trace, &ci, EnergyModel::default(), SimConfig::default())
            .with_shards(4)
            .run(&mut FixedTimeout::huawei());
        assert_eq!(r.metrics.invocations, 0);
    }
}
