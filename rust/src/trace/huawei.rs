//! CSV loader for real Huawei-trace exports.
//!
//! The public Huawei release ships per-day request tables; after joining
//! request logs with the cold-start log and metadata (the paper's §IV-A2
//! pre-processing), the natural flat export has one row per invocation:
//!
//! ```csv
//! timestamp_s,func_id,runtime,trigger,mem_mb,cpu_cores,exec_s,cold_start_s
//! 0.124,fn_ab12,python,http,64,1,0.21,0.35
//! ```
//!
//! `func_id` may be any string (the trace uses hashes); ids are densified
//! in first-seen order. Per-function attributes are aggregated across rows
//! (mean exec, first-seen resources, max cold-start estimate).

use std::collections::HashMap;

use crate::trace::model::{FunctionProfile, Invocation, Runtime, Trace, TriggerType};
use crate::util::csv::Table;

/// Load a joined-invocation CSV (schema above) into a [`Trace`].
pub fn load_csv(path: &str) -> anyhow::Result<Trace> {
    let table = Table::load(path)?;
    from_table(&table)
}

/// Parse an already-read CSV table (unit-testable without touching disk).
pub fn from_table(table: &Table) -> anyhow::Result<Trace> {
    let col = |name: &str| -> anyhow::Result<usize> {
        table
            .col(name)
            .ok_or_else(|| anyhow::anyhow!("missing column '{name}'"))
    };
    let c_t = col("timestamp_s")?;
    let c_func = col("func_id")?;
    let c_runtime = col("runtime")?;
    let c_trigger = col("trigger")?;
    let c_mem = col("mem_mb")?;
    let c_cpu = col("cpu_cores")?;
    let c_exec = col("exec_s")?;
    let c_cold = col("cold_start_s")?;

    let mut ids: HashMap<String, u32> = HashMap::new();
    let mut functions: Vec<FunctionProfile> = Vec::new();
    let mut exec_sums: Vec<(f64, u64)> = Vec::new();
    let mut invocations = Vec::with_capacity(table.rows.len());

    for (ri, row) in table.rows.iter().enumerate() {
        let ctx = |c: &str| format!("row {}: {}", ri + 2, c);
        let t: f64 = row[c_t].parse().map_err(|_| anyhow::anyhow!(ctx("bad timestamp")))?;
        let exec_s: f64 = row[c_exec].parse().map_err(|_| anyhow::anyhow!(ctx("bad exec_s")))?;
        let name = &row[c_func];
        let func = match ids.get(name) {
            Some(&id) => id,
            None => {
                let id = functions.len() as u32;
                let runtime = Runtime::from_name(&row[c_runtime])
                    .ok_or_else(|| anyhow::anyhow!(ctx("unknown runtime")))?;
                let trigger = TriggerType::from_name(&row[c_trigger])
                    .ok_or_else(|| anyhow::anyhow!(ctx("unknown trigger")))?;
                functions.push(FunctionProfile {
                    id,
                    runtime,
                    trigger,
                    mem_mb: row[c_mem].parse().map_err(|_| anyhow::anyhow!(ctx("bad mem_mb")))?,
                    cpu_cores: row[c_cpu]
                        .parse()
                        .map_err(|_| anyhow::anyhow!(ctx("bad cpu_cores")))?,
                    cold_start_s: row[c_cold]
                        .parse()
                        .map_err(|_| anyhow::anyhow!(ctx("bad cold_start_s")))?,
                    mean_exec_s: 0.0, // filled from aggregation below
                });
                exec_sums.push((0.0, 0));
                ids.insert(name.clone(), id);
                id
            }
        };
        let fs = &mut exec_sums[func as usize];
        fs.0 += exec_s;
        fs.1 += 1;
        invocations.push(Invocation { t, func, exec_s });
    }

    for (f, &(sum, n)) in functions.iter_mut().zip(exec_sums.iter()) {
        f.mean_exec_s = if n > 0 { sum / n as f64 } else { 0.0 };
    }

    invocations.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
    let trace = Trace::new(functions, invocations);
    trace.assert_sorted();
    Ok(trace)
}

/// Export a trace to the same CSV schema (round-trip for archiving the
/// synthetic workloads used in EXPERIMENTS.md).
pub fn save_csv(trace: &Trace, path: &str) -> anyhow::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = crate::util::csv::Writer::new(
        std::io::BufWriter::new(f),
        &[
            "timestamp_s",
            "func_id",
            "runtime",
            "trigger",
            "mem_mb",
            "cpu_cores",
            "exec_s",
            "cold_start_s",
        ],
    )?;
    for inv in &trace.invocations {
        let p = trace.profile(inv.func);
        w.row(&[
            format!("{:.6}", inv.t),
            format!("fn_{:05}", inv.func),
            p.runtime.name().to_string(),
            p.trigger.name().to_string(),
            format!("{:.1}", p.mem_mb),
            format!("{}", p.cpu_cores),
            format!("{:.6}", inv.exec_s),
            format!("{:.4}", p.cold_start_s),
        ])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth::{SynthConfig, TraceGenerator};
    use std::io::Cursor;

    const SAMPLE: &str = "\
timestamp_s,func_id,runtime,trigger,mem_mb,cpu_cores,exec_s,cold_start_s
1.5,fn_a,python,http,64,1,0.2,0.3
0.5,fn_b,custom,queue,256,2,1.0,8.0
2.5,fn_a,python,http,64,1,0.4,0.3
";

    #[test]
    fn parses_and_sorts() {
        let t = Table::read(Cursor::new(SAMPLE)).unwrap();
        let trace = from_table(&t).unwrap();
        assert_eq!(trace.functions.len(), 2);
        assert_eq!(trace.len(), 3);
        assert_eq!(trace.invocations[0].t, 0.5); // sorted
        let fa = trace.profile(0);
        assert_eq!(fa.runtime, Runtime::Python);
        assert!((fa.mean_exec_s - 0.3).abs() < 1e-12); // (0.2+0.4)/2
    }

    #[test]
    fn missing_column_is_error() {
        let t = Table::read(Cursor::new("timestamp_s,func_id\n1,fn\n")).unwrap();
        assert!(from_table(&t).is_err());
    }

    #[test]
    fn bad_value_reports_row() {
        let bad = SAMPLE.replace("1.5", "zzz");
        let t = Table::read(Cursor::new(bad)).unwrap();
        let err = from_table(&t).unwrap_err().to_string();
        assert!(err.contains("bad timestamp"), "{err}");
    }

    #[test]
    fn roundtrip_through_disk() {
        let trace = TraceGenerator::new(SynthConfig {
            n_functions: 10,
            duration_s: 100.0,
            target_invocations: 500,
            ..SynthConfig::small(9)
        })
        .generate();
        let path = std::env::temp_dir().join("lace_rl_trace_roundtrip.csv");
        let path = path.to_str().unwrap();
        save_csv(&trace, path).unwrap();
        let loaded = load_csv(path).unwrap();
        assert_eq!(loaded.len(), trace.len());
        // func ids are densified in first-seen order, so profiles may be
        // permuted; compare invocation timestamps + per-invocation runtime.
        for (a, b) in trace.invocations.iter().zip(loaded.invocations.iter()) {
            assert!((a.t - b.t).abs() < 1e-5);
            assert_eq!(
                trace.profile(a.func).runtime,
                loaded.profile(b.func).runtime
            );
        }
        let _ = std::fs::remove_file(path);
    }
}
