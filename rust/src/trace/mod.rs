//! Serverless workload traces: model, synthetic generator, loader, stats.
//!
//! The paper evaluates on day 30 of the Huawei Public Cloud Trace (300M+
//! request records, 1,500+ functions). That dataset is proprietary-download;
//! per the substitution rule we build a *generative* model of it
//! ([`synth`]) calibrated to the paper's published marginals (Fig. 1a reuse
//! intervals, Fig. 1b cold-start latency CDF, Fig. 3b memory CDF, Table I
//! runtime/trigger metadata), plus a CSV [`huawei`] loader that accepts the
//! real trace when available.

pub mod huawei;
pub mod model;
pub mod stats;
pub mod synth;

pub use model::{FunctionProfile, Invocation, Runtime, Trace, TriggerType};
pub use synth::{SynthConfig, TraceGenerator};
