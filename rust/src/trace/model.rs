//! Trace data model mirroring the Huawei Public Cloud Trace schema
//! (Table I of the paper): request-level logs (timestamp, podID, exec time,
//! CPU/mem requests), cold-start logs (latency breakdowns by runtime), and
//! the runtime/trigger metadata table.

/// Function runtime language — drives the cold-start latency profile
/// (paper Fig. 1b: sub-second for scripting runtimes, multi-second for
/// "Custom" images with heavy initialization).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Runtime {
    Python,
    NodeJs,
    Java,
    Go,
    /// Custom container images: long-tailed cold starts (model loading,
    /// large dependencies) — the paper's "Long-tailed" workload is mostly
    /// these.
    Custom,
}

impl Runtime {
    pub const ALL: [Runtime; 5] = [
        Runtime::Python,
        Runtime::NodeJs,
        Runtime::Java,
        Runtime::Go,
        Runtime::Custom,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Runtime::Python => "python",
            Runtime::NodeJs => "nodejs",
            Runtime::Java => "java",
            Runtime::Go => "go",
            Runtime::Custom => "custom",
        }
    }

    pub fn from_name(s: &str) -> Option<Runtime> {
        match s.to_ascii_lowercase().as_str() {
            "python" | "python3" => Some(Runtime::Python),
            "nodejs" | "node" | "js" => Some(Runtime::NodeJs),
            "java" => Some(Runtime::Java),
            "go" | "golang" => Some(Runtime::Go),
            "custom" | "container" => Some(Runtime::Custom),
            _ => None,
        }
    }
}

/// Invocation trigger type (Table I metadata).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TriggerType {
    Http,
    Timer,
    Queue,
    Storage,
}

impl TriggerType {
    pub const ALL: [TriggerType; 4] = [
        TriggerType::Http,
        TriggerType::Timer,
        TriggerType::Queue,
        TriggerType::Storage,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            TriggerType::Http => "http",
            TriggerType::Timer => "timer",
            TriggerType::Queue => "queue",
            TriggerType::Storage => "storage",
        }
    }

    pub fn from_name(s: &str) -> Option<TriggerType> {
        match s.to_ascii_lowercase().as_str() {
            "http" => Some(TriggerType::Http),
            "timer" => Some(TriggerType::Timer),
            "queue" => Some(TriggerType::Queue),
            "storage" => Some(TriggerType::Storage),
            _ => None,
        }
    }
}

/// Static per-function metadata (the trace's runtime/trigger table joined
/// with resource requests and the cold-start lookup profile).
#[derive(Debug, Clone)]
pub struct FunctionProfile {
    /// Dense id: index into `Trace::functions`.
    pub id: u32,
    pub runtime: Runtime,
    pub trigger: TriggerType,
    /// Memory request in MB (paper Fig. 3b: >80% under 100 MB).
    pub mem_mb: f64,
    /// CPU request in cores (most pods request 1 core; compute-heavy more).
    pub cpu_cores: f64,
    /// Expected cold-start latency in seconds (from the cold-start log
    /// lookup table, keyed by runtime/trigger — paper §IV-A2).
    pub cold_start_s: f64,
    /// Mean execution time in seconds.
    pub mean_exec_s: f64,
}

/// One request-level record.
#[derive(Debug, Clone, Copy)]
pub struct Invocation {
    /// Arrival timestamp, seconds from trace start.
    pub t: f64,
    /// Function id (index into `Trace::functions`).
    pub func: u32,
    /// Execution (compute-phase) duration in seconds.
    pub exec_s: f64,
}

/// Pre-computed partition of a trace into K contiguous-by-function-id
/// shards, for `simulator::sharded::ShardedSimulator`. Built once per
/// (trace, K) and cached on the [`Trace`].
#[derive(Debug)]
pub struct ShardIndex {
    /// Shard count this index was built for.
    pub k: usize,
    /// Function-id range of each shard; contiguous, covering `0..nf`.
    pub func_ranges: Vec<std::ops::Range<usize>>,
    /// Per shard, indices into `Trace::invocations` in arrival order —
    /// concatenating restores the full sorted stream when filtered back.
    pub invocations: Vec<Vec<u32>>,
}

impl ShardIndex {
    fn build(trace: &Trace, k: usize) -> ShardIndex {
        let nf = trace.functions.len();
        assert!(k >= 1 && k <= nf.max(1));
        assert!(
            trace.invocations.len() <= u32::MAX as usize,
            "shard index stores u32 invocation indices"
        );
        let func_ranges: Vec<std::ops::Range<usize>> =
            (0..k).map(|s| s * nf / k..(s + 1) * nf / k).collect();
        let mut shard_of = vec![0u32; nf];
        for (s, r) in func_ranges.iter().enumerate() {
            for f in r.clone() {
                shard_of[f] = s as u32;
            }
        }
        let mut invocations = vec![Vec::new(); k];
        // One forward scan: per-shard lists inherit global arrival order.
        for (i, inv) in trace.invocations.iter().enumerate() {
            invocations[shard_of[inv.func as usize] as usize].push(i as u32);
        }
        ShardIndex { k, func_ranges, invocations }
    }
}

/// Lazily-built `k -> ShardIndex` cache. Cloning a trace clones the data
/// but starts the cache cold — an index is only valid for the exact
/// invocation list it was built from, and the fields it indexes may be
/// edited on the clone.
#[derive(Debug, Default)]
pub struct ShardCache(
    std::sync::Mutex<std::collections::HashMap<usize, std::sync::Arc<ShardIndex>>>,
);

impl Clone for ShardCache {
    fn clone(&self) -> Self {
        ShardCache::default()
    }
}

/// A complete workload trace: function table + time-ordered invocations.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub functions: Vec<FunctionProfile>,
    /// Sorted by `t` ascending (enforced by loaders/generators).
    pub invocations: Vec<Invocation>,
    /// Private so every construction goes through [`Trace::new`] — direct
    /// field edits after construction would silently invalidate it anyway
    /// (the cache is keyed on the invocation list's content).
    shard_cache: ShardCache,
}

impl Trace {
    pub fn new(functions: Vec<FunctionProfile>, invocations: Vec<Invocation>) -> Trace {
        Trace { functions, invocations, shard_cache: ShardCache::default() }
    }

    /// Shard partition for `k` shards, built on first use and cached.
    /// `k` is clamped to `[1, nf]` by callers; repeated runs at the same
    /// shard count (sweeps, training episodes) pay the split once.
    pub fn shard_index(&self, k: usize) -> std::sync::Arc<ShardIndex> {
        let mut cache = self.shard_cache.0.lock().unwrap();
        std::sync::Arc::clone(
            cache
                .entry(k)
                .or_insert_with(|| std::sync::Arc::new(ShardIndex::build(self, k))),
        )
    }

    pub fn len(&self) -> usize {
        self.invocations.len()
    }

    pub fn is_empty(&self) -> bool {
        self.invocations.is_empty()
    }

    /// Trace duration in seconds (0 for empty traces).
    pub fn duration_s(&self) -> f64 {
        match (self.invocations.first(), self.invocations.last()) {
            (Some(a), Some(b)) => b.t - a.t,
            _ => 0.0,
        }
    }

    pub fn profile(&self, func: u32) -> &FunctionProfile {
        &self.functions[func as usize]
    }

    /// Verify the time-ordering invariant all consumers rely on.
    pub fn assert_sorted(&self) {
        debug_assert!(
            self.invocations.windows(2).all(|w| w[0].t <= w[1].t),
            "trace invocations must be sorted by arrival time"
        );
    }

    /// Split by invocation *count* fractions, preserving order — the
    /// paper's 80/10/10 train/validation/test partition (§IV-A2).
    pub fn split(&self, train: f64, valid: f64) -> (Trace, Trace, Trace) {
        assert!(train + valid <= 1.0);
        let n = self.invocations.len();
        let n_train = (n as f64 * train) as usize;
        let n_valid = (n as f64 * valid) as usize;
        let mk = |slice: &[Invocation]| Trace::new(self.functions.clone(), slice.to_vec());
        (
            mk(&self.invocations[..n_train]),
            mk(&self.invocations[n_train..n_train + n_valid]),
            mk(&self.invocations[n_train + n_valid..]),
        )
    }

    /// The paper's "Long-tailed" subset: invocations of functions whose
    /// cold-start latency falls in the distribution tail (≥ `thresh_s`).
    pub fn long_tail_subset(&self, thresh_s: f64) -> Trace {
        let keep: Vec<bool> = self
            .functions
            .iter()
            .map(|f| f.cold_start_s >= thresh_s)
            .collect();
        Trace::new(
            self.functions.clone(),
            self.invocations
                .iter()
                .filter(|i| keep[i.func as usize])
                .copied()
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_trace() -> Trace {
        let functions = vec![
            FunctionProfile {
                id: 0,
                runtime: Runtime::Python,
                trigger: TriggerType::Http,
                mem_mb: 64.0,
                cpu_cores: 1.0,
                cold_start_s: 0.2,
                mean_exec_s: 0.1,
            },
            FunctionProfile {
                id: 1,
                runtime: Runtime::Custom,
                trigger: TriggerType::Queue,
                mem_mb: 256.0,
                cpu_cores: 2.0,
                cold_start_s: 8.0,
                mean_exec_s: 1.0,
            },
        ];
        let invocations = (0..10)
            .map(|i| Invocation { t: i as f64, func: (i % 2) as u32, exec_s: 0.1 })
            .collect();
        Trace::new(functions, invocations)
    }

    #[test]
    fn split_preserves_counts_and_order() {
        let t = tiny_trace();
        let (tr, va, te) = t.split(0.8, 0.1);
        assert_eq!(tr.len(), 8);
        assert_eq!(va.len(), 1);
        assert_eq!(te.len(), 1);
        tr.assert_sorted();
        assert_eq!(tr.invocations[0].t, 0.0);
        assert_eq!(te.invocations[0].t, 9.0);
    }

    #[test]
    fn long_tail_filters_by_cold_start() {
        let t = tiny_trace();
        let lt = t.long_tail_subset(1.0);
        assert_eq!(lt.len(), 5);
        assert!(lt.invocations.iter().all(|i| i.func == 1));
    }

    #[test]
    fn runtime_name_roundtrip() {
        for r in Runtime::ALL {
            assert_eq!(Runtime::from_name(r.name()), Some(r));
        }
        assert_eq!(Runtime::from_name("COBOL"), None);
    }

    #[test]
    fn trigger_name_roundtrip() {
        for t in TriggerType::ALL {
            assert_eq!(TriggerType::from_name(t.name()), Some(t));
        }
    }

    #[test]
    fn duration() {
        let t = tiny_trace();
        assert_eq!(t.duration_s(), 9.0);
        assert_eq!(Trace::default().duration_s(), 0.0);
    }

    #[test]
    fn shard_index_partitions_functions_and_invocations() {
        let t = tiny_trace();
        for k in [1, 2] {
            let idx = t.shard_index(k);
            assert_eq!(idx.k, k);
            // Ranges are contiguous and cover 0..nf.
            assert_eq!(idx.func_ranges[0].start, 0);
            assert_eq!(idx.func_ranges[k - 1].end, t.functions.len());
            for w in idx.func_ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            // Every invocation lands in exactly one shard, arrival-ordered.
            let total: usize = idx.invocations.iter().map(|v| v.len()).sum();
            assert_eq!(total, t.len());
            for (s, list) in idx.invocations.iter().enumerate() {
                for w in list.windows(2) {
                    assert!(t.invocations[w[0] as usize].t <= t.invocations[w[1] as usize].t);
                }
                for &i in list {
                    let f = t.invocations[i as usize].func as usize;
                    assert!(idx.func_ranges[s].contains(&f));
                }
            }
        }
    }

    #[test]
    fn shard_index_is_cached_and_clone_starts_cold() {
        let t = tiny_trace();
        let a = t.shard_index(2);
        let b = t.shard_index(2);
        assert!(std::sync::Arc::ptr_eq(&a, &b));
        let c = t.clone().shard_index(2);
        assert!(!std::sync::Arc::ptr_eq(&a, &c));
    }
}
