//! Trace characterization (paper §II-C, Figs. 1 and 3b).
//!
//! Produces the empirical distributions the paper uses to motivate adaptive
//! keep-alive: per-pod reuse-interval CDF, cold-start latency CDF, and the
//! memory-footprint CDF.

use crate::trace::model::Trace;
use crate::util::stats::Ecdf;

/// Per-function average reuse interval (gap between successive invocations
/// of the same function). At typical per-function concurrency ≈1 this
/// matches the paper's per-pod reuse interval; functions with fewer than
/// `min_gaps` observed gaps are dropped.
pub fn mean_reuse_intervals(trace: &Trace, min_gaps: u64) -> Vec<f64> {
    let n = trace.functions.len();
    let mut last: Vec<Option<f64>> = vec![None; n];
    let mut sums = vec![0.0f64; n];
    let mut counts = vec![0u64; n];
    for inv in &trace.invocations {
        let fi = inv.func as usize;
        if let Some(prev) = last[fi] {
            sums[fi] += inv.t - prev;
            counts[fi] += 1;
        }
        last[fi] = Some(inv.t);
    }
    sums.iter()
        .zip(counts.iter())
        .filter(|(_, &c)| c >= min_gaps)
        .map(|(&s, &c)| s / c as f64)
        .collect()
}

/// All raw reuse gaps (for the state encoder's window statistics tests).
pub fn all_reuse_gaps(trace: &Trace) -> Vec<f64> {
    let mut last: Vec<Option<f64>> = vec![None; trace.functions.len()];
    let mut gaps = Vec::new();
    for inv in &trace.invocations {
        let fi = inv.func as usize;
        if let Some(prev) = last[fi] {
            gaps.push(inv.t - prev);
        }
        last[fi] = Some(inv.t);
    }
    gaps
}

/// Fig. 1a: CDF of per-pod average reuse intervals.
pub fn reuse_interval_cdf(trace: &Trace) -> Ecdf {
    Ecdf::new(mean_reuse_intervals(trace, 3))
}

/// Fig. 1b: CDF of cold-start latency across invocations.
pub fn cold_start_cdf(trace: &Trace) -> Ecdf {
    Ecdf::new(
        trace
            .invocations
            .iter()
            .map(|i| trace.profile(i.func).cold_start_s)
            .collect(),
    )
}

/// Fig. 3b: CDF of per-invocation memory footprint (MB).
pub fn memory_cdf(trace: &Trace) -> Ecdf {
    Ecdf::new(
        trace
            .invocations
            .iter()
            .map(|i| trace.profile(i.func).mem_mb)
            .collect(),
    )
}

/// Invocation counts per function (popularity profile).
pub fn invocation_counts(trace: &Trace) -> Vec<u64> {
    let mut counts = vec![0u64; trace.functions.len()];
    for inv in &trace.invocations {
        counts[inv.func as usize] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::model::{FunctionProfile, Invocation, Runtime, TriggerType};

    fn two_fn_trace() -> Trace {
        let mk = |id, cold, mem| FunctionProfile {
            id,
            runtime: Runtime::Python,
            trigger: TriggerType::Http,
            mem_mb: mem,
            cpu_cores: 1.0,
            cold_start_s: cold,
            mean_exec_s: 0.1,
        };
        // fn0 at t=0,1,2,3,4 (gap 1); fn1 at t=0,10,20,30 (gap 10)
        let mut invocations = Vec::new();
        for i in 0..5 {
            invocations.push(Invocation { t: i as f64, func: 0, exec_s: 0.1 });
        }
        for i in 0..4 {
            invocations.push(Invocation { t: 10.0 * i as f64, func: 1, exec_s: 0.1 });
        }
        invocations.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
        Trace::new(vec![mk(0, 0.5, 50.0), mk(1, 5.0, 200.0)], invocations)
    }

    #[test]
    fn mean_reuse_per_function() {
        let t = two_fn_trace();
        let mut means = mean_reuse_intervals(&t, 3);
        means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(means, vec![1.0, 10.0]);
    }

    #[test]
    fn min_gaps_filters() {
        let t = two_fn_trace();
        assert_eq!(mean_reuse_intervals(&t, 4).len(), 1); // fn1 has only 3 gaps
    }

    #[test]
    fn all_gaps_count() {
        let t = two_fn_trace();
        assert_eq!(all_reuse_gaps(&t).len(), 4 + 3);
    }

    #[test]
    fn cdfs_weighted_by_invocations() {
        let t = two_fn_trace();
        let cs = cold_start_cdf(&t);
        // 5 of 9 invocations have cold_start 0.5
        assert!((cs.eval(1.0) - 5.0 / 9.0).abs() < 1e-12);
        let mem = memory_cdf(&t);
        assert!((mem.eval(100.0) - 5.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn popularity_counts() {
        let t = two_fn_trace();
        assert_eq!(invocation_counts(&t), vec![5, 4]);
    }
}
