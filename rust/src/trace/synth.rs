//! Synthetic Huawei-like workload generator.
//!
//! Substitutes the proprietary Huawei Public Cloud Trace with a generative
//! model calibrated to every published marginal the keep-alive policies are
//! sensitive to (see DESIGN.md §3):
//!
//! * **Reuse intervals** (Fig. 1a): per-function arrival rates follow a
//!   Zipf popularity law spread over ~5 orders of magnitude, so mean reuse
//!   gaps span milliseconds to hundreds of seconds.
//! * **Cold-start latency** (Fig. 1b): per-runtime lognormal mixtures;
//!   scripting runtimes cluster at 0.1–0.5 s, Java at ~1 s, `Custom`
//!   container images form the 1–15 s long tail.
//! * **Memory footprint** (Fig. 3b): lognormal with >80% of invocations
//!   under 100 MB.
//! * **Arrival dynamics** (§IV-D "bursty arrival patterns"): a mix of
//!   Poisson, ON/OFF bursty (MMPP-2), and periodic (timer-trigger) streams,
//!   with an optional diurnal rate modulation.

use crate::trace::model::{FunctionProfile, Invocation, Runtime, Trace, TriggerType};
use crate::util::rng::Rng;

/// Generator parameters.
///
/// Reuse-gap calibration: the paper picks its action set {1, 5, 10, 30} s
/// to match the 10th/50th/75th/90th percentiles of observed reuse
/// intervals (§IV-A4), i.e. ~90% of gaps are ≤30 s. Per-function mean
/// gaps are therefore drawn from LogNormal(ln `gap_median_s`,
/// `gap_sigma`); with the defaults (8 s, 1.4) the quantiles land at
/// ≈{1.3, 8, 21, 48} s with a tail past 200 s — the Fig. 1a shape.
///
/// `target_invocations = 0` keeps the calibrated rates as-is (paper-scale
/// runs); a non-zero value rescales all rates to hit that expected count
/// (unit tests / smoke runs), trading away the gap calibration.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub n_functions: usize,
    pub duration_s: f64,
    /// 0 = use calibrated rates; >0 = rescale to this expected total.
    pub target_invocations: usize,
    /// Median of the per-function mean reuse gap (s).
    pub gap_median_s: f64,
    /// Log-space sigma of the gap distribution.
    pub gap_sigma: f64,
    /// Fraction of *sparse* functions whose gaps come from a second mode
    /// around `sparse_gap_median_s` — the production trace's long tail
    /// that makes indiscriminate pre-warming catastrophically wasteful
    /// (Fig. 2 right: idle carbon ≫ execution carbon) and keeps the
    /// static 60 s window's cold-start rate high.
    pub sparse_frac: f64,
    pub sparse_gap_median_s: f64,
    /// Fraction of functions with bursty (ON/OFF) arrivals.
    pub bursty_frac: f64,
    /// Fraction of functions with periodic (timer) arrivals.
    pub periodic_frac: f64,
    /// Apply a diurnal (sinusoidal) rate modulation.
    pub diurnal: bool,
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            n_functions: 500,
            duration_s: 86_400.0,
            target_invocations: 0, // calibrated rates → ≈0.6M/day
            gap_median_s: 8.0,
            gap_sigma: 1.2,
            sparse_frac: 0.95,
            sparse_gap_median_s: 600.0,
            bursty_frac: 0.3,
            periodic_frac: 0.15,
            diurnal: true,
            seed: 7,
        }
    }
}

impl SynthConfig {
    /// A small config for unit tests and the quickstart example.
    pub fn small(seed: u64) -> Self {
        SynthConfig {
            n_functions: 40,
            duration_s: 3_600.0,
            target_invocations: 20_000,
            seed,
            ..SynthConfig::default()
        }
    }
}

/// How a function's invocations arrive.
#[derive(Debug, Clone, Copy)]
enum ArrivalKind {
    /// Homogeneous Poisson process at `rate` (1/s).
    Poisson { rate: f64 },
    /// MMPP-2: exponential ON periods with burst-rate arrivals, exponential
    /// OFF periods with none. Produces the bursty patterns §IV-D blames for
    /// the Oracle gap on long-tailed functions.
    Bursty { on_rate: f64, mean_on_s: f64, mean_off_s: f64 },
    /// Timer trigger: near-constant period with jitter.
    Periodic { period_s: f64, jitter_s: f64 },
}

pub struct TraceGenerator {
    cfg: SynthConfig,
}

impl TraceGenerator {
    pub fn new(cfg: SynthConfig) -> Self {
        TraceGenerator { cfg }
    }

    /// Generate the full trace (function table + sorted invocations).
    pub fn generate(&self) -> Trace {
        let mut rng = Rng::new(self.cfg.seed);
        let functions = self.gen_functions(&mut rng);
        let kinds = self.gen_arrival_kinds(&functions, &mut rng);

        let mut invocations: Vec<Invocation> = Vec::new();
        for (f, kind) in functions.iter().zip(kinds.iter()) {
            let mut frng = rng.fork(f.id as u64);
            self.gen_arrivals(f, *kind, &mut frng, &mut invocations);
        }
        invocations.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
        let trace = Trace::new(functions, invocations);
        trace.assert_sorted();
        trace
    }

    fn gen_functions(&self, rng: &mut Rng) -> Vec<FunctionProfile> {
        (0..self.cfg.n_functions)
            .map(|i| {
                let runtime = sample_runtime(rng);
                let trigger = sample_trigger(rng);
                let mem_mb = sample_memory_mb(runtime, rng);
                let cpu_cores = sample_cpu_cores(runtime, rng);
                let cold_start_s = sample_cold_start_s(runtime, rng);
                let mean_exec_s = sample_exec_s(runtime, rng);
                FunctionProfile {
                    id: i as u32,
                    runtime,
                    trigger,
                    mem_mb,
                    cpu_cores,
                    cold_start_s,
                    mean_exec_s,
                }
            })
            .collect()
    }

    fn gen_arrival_kinds(
        &self,
        functions: &[FunctionProfile],
        rng: &mut Rng,
    ) -> Vec<ArrivalKind> {
        // Per-function rates from the calibrated reuse-gap distribution
        // (see SynthConfig docs): gap_i ~ LogNormal, rate_i = 1/gap_i.
        let n = functions.len();
        let mut rates: Vec<f64> = (0..n)
            .map(|_| {
                let gap = if rng.chance(self.cfg.sparse_frac) {
                    rng.lognormal(self.cfg.sparse_gap_median_s.ln(), 1.0)
                        .clamp(60.0, 7_200.0)
                } else {
                    rng.lognormal(self.cfg.gap_median_s.ln(), self.cfg.gap_sigma)
                        .clamp(0.3, 7_200.0)
                };
                1.0 / gap
            })
            .collect();
        // Optional rescale for bounded smoke workloads.
        if self.cfg.target_invocations > 0 {
            let natural: f64 = rates.iter().sum::<f64>() * self.cfg.duration_s;
            let scale = self.cfg.target_invocations as f64 / natural.max(1.0);
            for r in rates.iter_mut() {
                *r *= scale;
            }
        }

        functions
            .iter()
            .map(|f| {
                let rate = rates[f.id as usize];
                if f.trigger == TriggerType::Timer
                    || rng.chance(self.cfg.periodic_frac)
                {
                    // Period from the rate, clamped to a sane range.
                    let period = (1.0 / rate.max(1e-9)).clamp(1.0, 3600.0);
                    ArrivalKind::Periodic { period_s: period, jitter_s: period * 0.05 }
                } else if rng.chance(self.cfg.bursty_frac) {
                    // Bursts ~20x the base rate, ON ~5% of the time.
                    let mean_on = rng.range(5.0, 60.0);
                    let mean_off = mean_on * rng.range(10.0, 30.0);
                    let duty = mean_on / (mean_on + mean_off);
                    let on_rate = (rate / duty).max(rate);
                    ArrivalKind::Bursty { on_rate, mean_on_s: mean_on, mean_off_s: mean_off }
                } else {
                    ArrivalKind::Poisson { rate }
                }
            })
            .collect()
    }

    /// Diurnal modulation factor in [0.4, 1.6] peaking mid-day.
    fn diurnal_factor(&self, t: f64) -> f64 {
        if !self.cfg.diurnal {
            return 1.0;
        }
        let day_frac = (t / 86_400.0).fract();
        1.0 + 0.6 * (2.0 * std::f64::consts::PI * (day_frac - 0.25)).sin()
    }

    fn gen_arrivals(
        &self,
        f: &FunctionProfile,
        kind: ArrivalKind,
        rng: &mut Rng,
        out: &mut Vec<Invocation>,
    ) {
        let dur = self.cfg.duration_s;
        let mut push = |t: f64, rng: &mut Rng| {
            // Per-invocation execution time jitters around the function mean.
            let exec = f.mean_exec_s * rng.lognormal(0.0, 0.4);
            out.push(Invocation { t, func: f.id, exec_s: exec });
        };
        match kind {
            ArrivalKind::Poisson { rate } => {
                if rate <= 0.0 {
                    return;
                }
                // Thinning for the diurnal modulation: generate at the max
                // rate, accept with prob factor/max.
                let max_factor = 1.6;
                let mut t = 0.0;
                loop {
                    t += rng.exp(rate * max_factor);
                    if t >= dur {
                        break;
                    }
                    if rng.chance(self.diurnal_factor(t) / max_factor) {
                        push(t, rng);
                    }
                }
            }
            ArrivalKind::Bursty { on_rate, mean_on_s, mean_off_s } => {
                let mut t = rng.exp(1.0 / mean_off_s.max(1e-9));
                while t < dur {
                    // ON window
                    let on_end = (t + rng.exp(1.0 / mean_on_s)).min(dur);
                    let mut a = t;
                    loop {
                        a += rng.exp(on_rate.max(1e-9));
                        if a >= on_end {
                            break;
                        }
                        push(a, rng);
                    }
                    // OFF window
                    t = on_end + rng.exp(1.0 / mean_off_s);
                }
            }
            ArrivalKind::Periodic { period_s, jitter_s } => {
                let mut t = rng.range(0.0, period_s);
                while t < dur {
                    push(t, rng);
                    t += period_s + rng.normal(0.0, jitter_s).max(-period_s * 0.5);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Population marginals (calibrated to Figs. 1b / 3b and Table I)
// ---------------------------------------------------------------------------

fn sample_runtime(rng: &mut Rng) -> Runtime {
    // Weights approximate the Huawei runtime mix; `Custom` sized so the
    // long-tailed subset carries a majority of the cold-start *seconds*.
    let w = [0.35, 0.22, 0.13, 0.10, 0.20];
    Runtime::ALL[rng.categorical(&w)]
}

fn sample_trigger(rng: &mut Rng) -> TriggerType {
    let w = [0.55, 0.15, 0.20, 0.10];
    TriggerType::ALL[rng.categorical(&w)]
}

/// Memory request (MB). Fig. 3b: majority < 200 MB, >80% < 100 MB.
fn sample_memory_mb(runtime: Runtime, rng: &mut Rng) -> f64 {
    let (mu, sigma) = match runtime {
        Runtime::Custom => (4.3, 0.9), // median ~74 MB, tail to ~1 GB
        _ => (3.4, 0.9),               // median ~30 MB
    };
    rng.lognormal(mu, sigma).clamp(16.0, 4096.0)
}

fn sample_cpu_cores(runtime: Runtime, rng: &mut Rng) -> f64 {
    // Most pods request one core (§IV-A1); compute-heavy customs more.
    if runtime == Runtime::Custom && rng.chance(0.3) {
        *rng.choice(&[2.0, 4.0])
    } else if rng.chance(0.05) {
        2.0
    } else {
        1.0
    }
}

/// Cold-start latency (s), per runtime. Fig. 1b: 0.1 s … >10 s, long tail.
fn sample_cold_start_s(runtime: Runtime, rng: &mut Rng) -> f64 {
    let (mu, sigma, min, max) = match runtime {
        Runtime::Python => (-1.35, 0.45, 0.08, 3.0), // median ~0.26 s
        Runtime::NodeJs => (-1.60, 0.40, 0.06, 2.0), // median ~0.20 s
        Runtime::Java => (0.10, 0.50, 0.30, 6.0),    // median ~1.1 s
        Runtime::Go => (-1.90, 0.40, 0.05, 1.5),     // median ~0.15 s
        Runtime::Custom => (1.50, 0.80, 0.80, 20.0), // median ~4.5 s, tail >10 s
    };
    rng.lognormal(mu, sigma).clamp(min, max)
}

/// Mean execution time (s).
fn sample_exec_s(runtime: Runtime, rng: &mut Rng) -> f64 {
    let (mu, sigma) = match runtime {
        Runtime::Custom => (-0.2, 1.0), // median ~0.8 s
        _ => (-1.6, 1.0),               // median ~0.2 s
    };
    rng.lognormal(mu, sigma).clamp(0.001, 120.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Ecdf;

    fn small_trace() -> Trace {
        TraceGenerator::new(SynthConfig::small(1)).generate()
    }

    #[test]
    fn deterministic_in_seed() {
        let a = TraceGenerator::new(SynthConfig::small(5)).generate();
        let b = TraceGenerator::new(SynthConfig::small(5)).generate();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.invocations.iter().zip(b.invocations.iter()) {
            assert_eq!(x.t, y.t);
            assert_eq!(x.func, y.func);
        }
    }

    #[test]
    fn different_seed_different_trace() {
        let a = TraceGenerator::new(SynthConfig::small(1)).generate();
        let b = TraceGenerator::new(SynthConfig::small(2)).generate();
        assert_ne!(a.len(), b.len());
    }

    #[test]
    fn invocation_count_near_target() {
        let t = small_trace();
        let target = SynthConfig::small(1).target_invocations as f64;
        // Bursty duty-cycle approximation and periodic-period clamping make
        // the realized count noisy (especially with many sparse functions);
        // accept a wide band — the full-scale configs use calibrated rates
        // (target_invocations = 0) where this does not apply.
        assert!(
            (t.len() as f64) > target * 0.2 && (t.len() as f64) < target * 2.5,
            "len={} target={}",
            t.len(),
            target
        );
    }

    #[test]
    fn sorted_and_in_range() {
        let t = small_trace();
        t.assert_sorted();
        assert!(t.invocations.iter().all(|i| i.t >= 0.0 && i.t < 3_600.0));
        assert!(t.invocations.iter().all(|i| i.exec_s > 0.0));
    }

    #[test]
    fn memory_cdf_matches_paper_shape() {
        // Fig 3b: >80% of invocations use < ~100-150 MB.
        let cfg = SynthConfig { n_functions: 500, ..SynthConfig::small(3) };
        let t = TraceGenerator::new(cfg).generate();
        let mems: Vec<f64> = t.invocations.iter()
            .map(|i| t.profile(i.func).mem_mb)
            .collect();
        let cdf = Ecdf::new(mems);
        assert!(cdf.eval(150.0) > 0.7, "P[mem<=150MB]={}", cdf.eval(150.0));
    }

    #[test]
    fn cold_start_cdf_has_long_tail() {
        // Fig 1b: latencies span <0.1s to >10s.
        let cfg = SynthConfig { n_functions: 800, ..SynthConfig::small(4) };
        let t = TraceGenerator::new(cfg).generate();
        let cs: Vec<f64> = t.functions.iter().map(|f| f.cold_start_s).collect();
        let cdf = Ecdf::new(cs);
        assert!(cdf.min() < 0.2, "min={}", cdf.min());
        assert!(cdf.max() > 8.0, "max={}", cdf.max());
        // Majority sub-second, tail beyond:
        assert!(cdf.eval(1.0) > 0.5);
        assert!(cdf.eval(1.0) < 0.95);
    }

    #[test]
    fn reuse_intervals_span_orders_of_magnitude() {
        let t = small_trace();
        // Per-function mean inter-arrival gaps.
        let mut last: Vec<Option<f64>> = vec![None; t.functions.len()];
        let mut sums = vec![0.0f64; t.functions.len()];
        let mut counts = vec![0u64; t.functions.len()];
        for inv in &t.invocations {
            let fi = inv.func as usize;
            if let Some(prev) = last[fi] {
                sums[fi] += inv.t - prev;
                counts[fi] += 1;
            }
            last[fi] = Some(inv.t);
        }
        let means: Vec<f64> = sums
            .iter()
            .zip(counts.iter())
            .filter(|(_, &c)| c > 3)
            .map(|(&s, &c)| s / c as f64)
            .collect();
        let lo = means.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = means.iter().cloned().fold(0.0, f64::max);
        assert!(hi / lo > 100.0, "reuse interval spread too narrow: {lo}..{hi}");
    }

    #[test]
    fn long_tail_subset_is_custom_heavy() {
        let t = small_trace();
        let lt = t.long_tail_subset(1.0);
        assert!(!lt.is_empty());
        // The ≥1s cold-start tail is dominated by Custom images with Java
        // as the secondary contributor (Fig. 1b shape).
        let custom_or_java = lt
            .invocations
            .iter()
            .filter(|i| {
                matches!(
                    t.profile(i.func).runtime,
                    Runtime::Custom | Runtime::Java
                )
            })
            .count();
        assert!(custom_or_java as f64 / lt.len() as f64 > 0.8);
    }
}
