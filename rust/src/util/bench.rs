//! Bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets under `benches/` use `harness = false` and call
//! into this module: warmup, calibrated iteration counts, median/mean/p99
//! over sample batches, and criterion-style output lines that
//! `bench_output.txt` captures. [`Report`] additionally exports the
//! summaries machine-readably (`BENCH_sim.json`) so the perf trajectory is
//! tracked across PRs (EXPERIMENTS.md §Perf).

use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One benchmark measurement summary (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct Summary {
    pub name: String,
    pub samples: usize,
    pub iters_per_sample: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub throughput_per_s: f64,
}

impl Summary {
    pub fn print(&self) {
        println!(
            "{:<44} time: [{} {} {}]  thrpt: {:>12.0}/s  ({} samples x {} iters)",
            self.name,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p99_ns),
            self.throughput_per_s,
            self.samples,
            self.iters_per_sample,
        );
    }
}

/// Format nanoseconds human-readably (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2}µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2}ms", ns / 1_000_000.0)
    } else {
        format!("{:.3}s", ns / 1_000_000_000.0)
    }
}

/// Benchmark a closure. Warm up for `warmup`, then collect `samples`
/// batches sized so each batch runs ≥ `min_batch`. Returns the summary
/// (already printed).
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> Summary {
    bench_cfg(name, Duration::from_millis(200), 30, Duration::from_millis(10), &mut f)
}

/// Quick variant for expensive end-to-end benches (few samples, no repeat).
pub fn bench_once<F: FnMut()>(name: &str, samples: usize, mut f: F) -> Summary {
    // Warm once to populate caches/JIT-like effects.
    f();
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        per_iter.push(t0.elapsed().as_nanos() as f64);
    }
    summarize(name, 1, per_iter)
}

pub fn bench_cfg<F: FnMut()>(
    name: &str,
    warmup: Duration,
    samples: usize,
    min_batch: Duration,
    f: &mut F,
) -> Summary {
    // Warmup + calibration: find iters/batch so a batch takes >= min_batch.
    let mut iters: u64 = 1;
    let warm_start = Instant::now();
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed();
        if dt >= min_batch {
            break;
        }
        iters = (iters * 2).max((iters as f64 * min_batch.as_nanos() as f64
            / dt.as_nanos().max(1) as f64) as u64);
        if warm_start.elapsed() > warmup && iters > 1 {
            break;
        }
    }
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    summarize(name, iters, per_iter)
}

fn summarize(name: &str, iters: u64, mut per_iter: Vec<f64>) -> Summary {
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = per_iter.len();
    let mean = per_iter.iter().sum::<f64>() / n as f64;
    let median = per_iter[n / 2];
    let p99 = per_iter[((n as f64 * 0.99) as usize).min(n - 1)];
    let min = per_iter[0];
    let s = Summary {
        name: name.to_string(),
        samples: n,
        iters_per_sample: iters,
        mean_ns: mean,
        median_ns: median,
        p99_ns: p99,
        min_ns: min,
        throughput_per_s: 1e9 / median,
    };
    s.print();
    s
}

/// Opaque value sink preventing the optimizer from deleting benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Collects [`Summary`]s and writes them as one JSON document keyed by
/// bench label: `{"schema": 1, "benches": {label: {median_ns, ...,
/// throughput_per_s}}}`. CI (`scripts/bench_smoke.sh`) diffs these across
/// PRs.
#[derive(Debug, Default)]
pub struct Report {
    entries: Vec<Summary>,
}

impl Report {
    pub fn new() -> Self {
        Report::default()
    }

    pub fn add(&mut self, summary: Summary) {
        self.entries.push(summary);
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let benches = self
            .entries
            .iter()
            .map(|s| {
                (
                    s.name.clone(),
                    Json::obj(vec![
                        ("median_ns", Json::Num(s.median_ns)),
                        ("mean_ns", Json::Num(s.mean_ns)),
                        ("p99_ns", Json::Num(s.p99_ns)),
                        ("min_ns", Json::Num(s.min_ns)),
                        ("throughput_per_s", Json::Num(s.throughput_per_s)),
                        ("samples", Json::Num(s.samples as f64)),
                        ("iters_per_sample", Json::Num(s.iters_per_sample as f64)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("benches", Json::Obj(benches)),
        ])
    }

    /// Write the report to `path` (e.g. `BENCH_sim.json`).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let s = bench_cfg(
            "noop-ish",
            Duration::from_millis(5),
            5,
            Duration::from_micros(100),
            &mut || {
                acc = black_box(acc.wrapping_add(1));
            },
        );
        assert!(s.median_ns > 0.0);
        assert!(s.median_ns < 1_000_000.0); // well under 1ms
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(12.0), "12.0ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2_500_000.0), "2.50ms");
        assert_eq!(fmt_ns(3_000_000_000.0), "3.000s");
    }

    #[test]
    fn report_round_trips_as_json() {
        let mut r = Report::new();
        r.add(Summary {
            name: "sim/fixed-60s".to_string(),
            samples: 5,
            iters_per_sample: 1,
            mean_ns: 1000.0,
            median_ns: 900.0,
            p99_ns: 1500.0,
            min_ns: 800.0,
            throughput_per_s: 1e9 / 900.0,
        });
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_usize(), Some(1));
        let entry = j.get("benches").unwrap().get("sim/fixed-60s").unwrap();
        assert_eq!(entry.get("median_ns").unwrap().as_f64(), Some(900.0));
        assert!(entry.get("throughput_per_s").unwrap().as_f64().unwrap() > 1e6);
    }

    #[test]
    fn bench_once_runs_n_samples() {
        let mut count = 0;
        let s = bench_once("counter", 4, || {
            count += 1;
        });
        assert_eq!(count, 5); // 1 warmup + 4 samples
        assert_eq!(s.samples, 4);
    }
}
