//! Minimal CLI argument parser: `binary <subcommand> [--key value] [--flag]`.
//!
//! Replaces `clap` (unavailable offline). Supports subcommands, `--key value`
//! options, `--key=value`, boolean flags, and positional arguments; prints
//! generated usage text on error.

use std::collections::BTreeMap;

/// Parsed arguments for one invocation.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        // First non-flag token is the subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.opts.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_opts() {
        let a = parse("simulate --policy lace-rl --seed 7 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.opt("policy"), Some("lace-rl"));
        assert_eq!(a.u64_or("seed", 0), 7);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("train --lambda=0.5 --episodes=300");
        assert_eq!(a.f64_or("lambda", 0.0), 0.5);
        assert_eq!(a.u64_or("episodes", 0), 300);
    }

    #[test]
    fn trailing_flag_not_eaten() {
        let a = parse("x --flag1 --key v --flag2");
        assert!(a.flag("flag1"));
        assert!(a.flag("flag2"));
        assert_eq!(a.opt("key"), Some("v"));
    }

    #[test]
    fn positional_args() {
        let a = parse("experiment fig5 extra");
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig5", "extra"]);
    }

    #[test]
    fn no_subcommand_when_flag_first() {
        let a = parse("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.f64_or("missing", 2.5), 2.5);
        assert_eq!(a.str_or("missing", "dft"), "dft");
    }
}
