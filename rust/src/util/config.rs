//! TOML-subset experiment configuration.
//!
//! Supports the subset the launcher needs: `[section]` tables, `key = value`
//! with strings, integers, floats, booleans, and flat arrays of scalars.
//! Comments start with `#`. No nested tables-in-arrays, no dates, no
//! multi-line strings — experiments don't need them.
//!
//! ```toml
//! [workload]
//! functions = 400
//! duration_s = 86400.0
//! seed = 7
//!
//! [policy]
//! name = "lace-rl"
//! lambda_carbon = 0.5
//! ```

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64_arr(&self) -> Option<Vec<f64>> {
        match self {
            Value::Arr(xs) => xs.iter().map(|x| x.as_f64()).collect(),
            _ => None,
        }
    }
}

/// Parsed config: `section.key -> value`. Keys before any `[section]` live
/// in the "" section.
#[derive(Debug, Clone, Default)]
pub struct Config {
    map: BTreeMap<String, BTreeMap<String, Value>>,
}

#[derive(Debug, thiserror::Error)]
#[error("config parse error on line {line}: {msg}")]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl Config {
    pub fn parse(src: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| ConfigError { line: lineno + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| err("missing ']'"))?;
                section = name.trim().to_string();
                cfg.map.entry(section.clone()).or_default();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| err("missing '='"))?;
            let value = parse_value(val.trim()).map_err(|m| err(&m))?;
            cfg.map
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> anyhow::Result<Config> {
        let src = std::fs::read_to_string(path)?;
        Ok(Config::parse(&src)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.map.get(section).and_then(|m| m.get(key))
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.i64_or(section, key, default as i64) as usize
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }

    pub fn set(&mut self, section: &str, key: &str, value: Value) {
        self.map
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value);
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is respected.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    s.parse::<f64>()
        .map(Value::Float)
        .map_err(|_| format!("invalid value: {s}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
title = "general"

[workload]
functions = 400
duration_s = 86400.0   # one day
bursty = true
weights = [0.5, 0.3, 0.2]

[policy]
name = "lace-rl"
lambda_carbon = 0.5
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("", "title", "?"), "general");
        assert_eq!(c.i64_or("workload", "functions", 0), 400);
        assert_eq!(c.f64_or("workload", "duration_s", 0.0), 86400.0);
        assert!(c.bool_or("workload", "bursty", false));
        assert_eq!(
            c.get("workload", "weights").unwrap().as_f64_arr().unwrap(),
            vec![0.5, 0.3, 0.2]
        );
        assert_eq!(c.str_or("policy", "name", "?"), "lace-rl");
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.f64_or("x", "y", 1.5), 1.5);
        assert_eq!(c.str_or("x", "y", "dft"), "dft");
    }

    #[test]
    fn comment_inside_string_kept() {
        let c = Config::parse("k = \"a#b\"").unwrap();
        assert_eq!(c.str_or("", "k", "?"), "a#b");
    }

    #[test]
    fn error_reports_line() {
        let e = Config::parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn int_vs_float() {
        let c = Config::parse("a = 3\nb = 3.0\nc = -2\n").unwrap();
        assert_eq!(c.get("", "a"), Some(&Value::Int(3)));
        assert_eq!(c.get("", "b"), Some(&Value::Float(3.0)));
        assert_eq!(c.get("", "c"), Some(&Value::Int(-2)));
    }

    #[test]
    fn set_overrides() {
        let mut c = Config::parse("").unwrap();
        c.set("policy", "name", Value::Str("oracle".into()));
        assert_eq!(c.str_or("policy", "name", "?"), "oracle");
    }
}
