//! Minimal CSV reader/writer with header support.
//!
//! Handles RFC 4180 quoting (quoted fields, embedded commas/quotes/newlines)
//! — enough to load real Huawei-trace exports and to emit figure data files.

use std::io::{BufRead, Write};

/// Parse one CSV record from a reader; returns None at EOF.
/// Handles quoted fields spanning multiple lines.
fn read_record<R: BufRead>(r: &mut R) -> std::io::Result<Option<Vec<String>>> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    // Accumulate more lines while inside an unterminated quote.
    while quote_open(&line) {
        let mut next = String::new();
        if r.read_line(&mut next)? == 0 {
            break;
        }
        line.push_str(&next);
    }
    Ok(Some(split_record(&line)))
}

fn quote_open(s: &str) -> bool {
    let mut open = false;
    let mut chars = s.chars().peekable();
    while let Some(c) = chars.next() {
        if c == '"' {
            if open && chars.peek() == Some(&'"') {
                chars.next(); // escaped quote
            } else {
                open = !open;
            }
        }
    }
    open
}

fn split_record(line: &str) -> Vec<String> {
    let line = line.strip_suffix('\n').unwrap_or(line);
    let line = line.strip_suffix('\r').unwrap_or(line);
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    cur.push('"');
                    chars.next();
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                fields.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    fields.push(cur);
    fields
}

/// A CSV table with named columns.
#[derive(Debug, Clone)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Read a table (first record is the header).
    pub fn read<R: BufRead>(mut r: R) -> std::io::Result<Table> {
        let header = read_record(&mut r)?.unwrap_or_default();
        let mut rows = Vec::new();
        while let Some(rec) = read_record(&mut r)? {
            if rec.len() == 1 && rec[0].is_empty() {
                continue; // blank line
            }
            rows.push(rec);
        }
        Ok(Table { header, rows })
    }

    pub fn load(path: &str) -> anyhow::Result<Table> {
        let f = std::fs::File::open(path)?;
        Ok(Table::read(std::io::BufReader::new(f))?)
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Typed access helpers.
    pub fn f64_at(&self, row: usize, col: usize) -> Option<f64> {
        self.rows.get(row)?.get(col)?.parse().ok()
    }

    pub fn str_at(&self, row: usize, col: usize) -> Option<&str> {
        self.rows.get(row)?.get(col).map(String::as_str)
    }
}

/// Streaming CSV writer.
pub struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    pub fn new(mut w: W, header: &[&str]) -> std::io::Result<Self> {
        write_row_raw(&mut w, header.iter().copied())?;
        Ok(Writer { w })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        write_row_raw(&mut self.w, fields.iter().map(String::as_str))
    }

    pub fn row_display<T: std::fmt::Display>(&mut self, fields: &[T]) -> std::io::Result<()> {
        let strs: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&strs)
    }

    pub fn into_inner(self) -> W {
        self.w
    }
}

fn needs_quoting(s: &str) -> bool {
    s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r')
}

fn write_row_raw<'a, W: Write>(
    w: &mut W,
    fields: impl Iterator<Item = &'a str>,
) -> std::io::Result<()> {
    let mut first = true;
    for f in fields {
        if !first {
            write!(w, ",")?;
        }
        first = false;
        if needs_quoting(f) {
            write!(w, "\"{}\"", f.replace('"', "\"\""))?;
        } else {
            write!(w, "{f}")?;
        }
    }
    writeln!(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn basic_roundtrip() {
        let src = "a,b,c\n1,2,3\n4,5,6\n";
        let t = Table::read(Cursor::new(src)).unwrap();
        assert_eq!(t.header, vec!["a", "b", "c"]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.f64_at(1, 2), Some(6.0));
        assert_eq!(t.col("b"), Some(1));
    }

    #[test]
    fn quoted_fields() {
        let src = "name,desc\nfn1,\"has, comma\"\nfn2,\"quote \"\" inside\"\n";
        let t = Table::read(Cursor::new(src)).unwrap();
        assert_eq!(t.str_at(0, 1), Some("has, comma"));
        assert_eq!(t.str_at(1, 1), Some("quote \" inside"));
    }

    #[test]
    fn multiline_quoted_field() {
        let src = "a,b\n1,\"line1\nline2\"\n";
        let t = Table::read(Cursor::new(src)).unwrap();
        assert_eq!(t.rows.len(), 1);
        assert!(t.str_at(0, 1).unwrap().contains('\n'));
    }

    #[test]
    fn writer_quotes_when_needed() {
        let mut out = Vec::new();
        {
            let mut w = Writer::new(&mut out, &["x", "y"]).unwrap();
            w.row(&["plain".into(), "with,comma".into()]).unwrap();
        }
        let s = String::from_utf8(out).unwrap();
        assert_eq!(s, "x,y\nplain,\"with,comma\"\n");
    }

    #[test]
    fn write_read_roundtrip() {
        let mut out = Vec::new();
        {
            let mut w = Writer::new(&mut out, &["k", "v"]).unwrap();
            w.row(&["a\"b".into(), "c\nd".into()]).unwrap();
        }
        let t = Table::read(Cursor::new(String::from_utf8(out).unwrap())).unwrap();
        assert_eq!(t.str_at(0, 0), Some("a\"b"));
        assert_eq!(t.str_at(0, 1), Some("c\nd"));
    }

    #[test]
    fn blank_lines_skipped() {
        let t = Table::read(Cursor::new("a\n1\n\n2\n")).unwrap();
        assert_eq!(t.rows.len(), 2);
    }
}
