//! Shared f32 GEMM / GEMV kernels for the Q-network hot paths.
//!
//! One register-blocked accumulation kernel ([`axpy`], 4 independent lanes,
//! no loop-carried dependency, autovectorizer-friendly) backs both the
//! per-decision inference path ([`crate::policy::native_mlp`], via
//! [`linear`]) and the batched training path
//! ([`crate::rl::native_train`], via [`gemm_bias`] and the backward
//! kernels). At the network's dims (64×64 f32 tiles) every operand is
//! L1-resident, so the blocking that matters is the 4-wide register tile —
//! there is no cache-level tiling to do.
//!
//! Numerics contract: [`gemm_bias`] applies [`linear`] row by row, so a
//! 1-row GEMM is **bit-identical** to the historical `NativeMlp` forward
//! (per-lane FP order unchanged) — the sharded-simulator bit-identity
//! property tests depend on this.

/// y += a * x, accumulated in 4-wide register blocks. Preserves per-lane
/// FP order (lane j only ever accumulates `a * x[j]`), so unrolling does
/// not change results vs the scalar loop.
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut yc = y.chunks_exact_mut(4);
    let mut xc = x.chunks_exact(4);
    for (yj, xj) in (&mut yc).zip(&mut xc) {
        yj[0] += a * xj[0];
        yj[1] += a * xj[1];
        yj[2] += a * xj[2];
        yj[3] += a * xj[3];
    }
    for (yj, &xj) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yj += a * xj;
    }
}

/// Dot product with 4 independent accumulator lanes (folded pairwise at
/// the end). Deterministic: the operation order is fixed, so results are
/// bit-identical across runs.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let mut ac = a.chunks_exact(4);
    let mut bc = b.chunks_exact(4);
    for (x4, y4) in (&mut ac).zip(&mut bc) {
        acc[0] += x4[0] * y4[0];
        acc[1] += x4[1] * y4[1];
        acc[2] += x4[2] * y4[2];
        acc[3] += x4[3] * y4[3];
    }
    for (&x, &y) in ac.remainder().iter().zip(bc.remainder()) {
        acc[0] += x * y;
    }
    (acc[0] + acc[1]) + (acc[2] + acc[3])
}

/// y = x @ W + b for one row. W is row-major `[in, out]`. Accumulates
/// row-wise so the inner loop streams W sequentially (cache-friendly for
/// row-major weights); zero inputs are skipped (ReLU sparsity).
#[inline]
pub fn linear(x: &[f32], w: &[f32], b: &[f32], y: &mut [f32]) {
    let n_out = y.len();
    debug_assert_eq!(w.len(), x.len() * n_out);
    y.copy_from_slice(b);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue; // ReLU sparsity: skip zeroed activations
        }
        axpy(xi, &w[i * n_out..(i + 1) * n_out], y);
    }
}

/// y = relu(x @ W + b) for one row.
#[inline]
pub fn linear_relu(x: &[f32], w: &[f32], b: &[f32], y: &mut [f32]) {
    linear(x, w, b, y);
    relu(y);
}

/// Clamp negatives to zero in place.
#[inline]
pub fn relu(y: &mut [f32]) {
    for v in y.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Y = X @ W + b, batched: X is `[rows, d_in]`, W row-major
/// `[d_in, d_out]`, b `[d_out]`, Y `[rows, d_out]` — all row-major flat
/// slices. Each row goes through [`linear`], so a 1-row call is
/// bit-identical to the inference path.
pub fn gemm_bias(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    y: &mut [f32],
    rows: usize,
    d_in: usize,
    d_out: usize,
) {
    debug_assert_eq!(x.len(), rows * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(b.len(), d_out);
    debug_assert_eq!(y.len(), rows * d_out);
    for r in 0..rows {
        linear(
            &x[r * d_in..(r + 1) * d_in],
            w,
            b,
            &mut y[r * d_out..(r + 1) * d_out],
        );
    }
}

/// GW = Xᵀ @ dY (weight gradient): X `[rows, d_in]`, dY `[rows, d_out]`,
/// GW row-major `[d_in, d_out]`, overwritten. Accumulates row-by-row with
/// the same [`axpy`] kernel as the forward pass; zero activations are
/// skipped (exact — their contribution is identically zero).
pub fn grad_weights(x: &[f32], dy: &[f32], gw: &mut [f32], rows: usize, d_in: usize, d_out: usize) {
    debug_assert_eq!(x.len(), rows * d_in);
    debug_assert_eq!(dy.len(), rows * d_out);
    debug_assert_eq!(gw.len(), d_in * d_out);
    gw.fill(0.0);
    for r in 0..rows {
        let xr = &x[r * d_in..(r + 1) * d_in];
        let dyr = &dy[r * d_out..(r + 1) * d_out];
        for (i, &xi) in xr.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            axpy(xi, dyr, &mut gw[i * d_out..(i + 1) * d_out]);
        }
    }
}

/// gb = column sums of dY (bias gradient): dY `[rows, d_out]`, gb
/// `[d_out]`, overwritten.
pub fn grad_bias(dy: &[f32], gb: &mut [f32], rows: usize, d_out: usize) {
    debug_assert_eq!(dy.len(), rows * d_out);
    debug_assert_eq!(gb.len(), d_out);
    gb.fill(0.0);
    for r in 0..rows {
        let dyr = &dy[r * d_out..(r + 1) * d_out];
        for (g, &d) in gb.iter_mut().zip(dyr.iter()) {
            *g += d;
        }
    }
}

/// dX = dY @ Wᵀ (input gradient): dY `[rows, d_out]`, W row-major
/// `[d_in, d_out]`, dX `[rows, d_in]`, overwritten. Both operands of the
/// inner [`dot`] stream contiguously (dY rows and W rows).
pub fn gemm_wt(dy: &[f32], w: &[f32], dx: &mut [f32], rows: usize, d_in: usize, d_out: usize) {
    debug_assert_eq!(dy.len(), rows * d_out);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(dx.len(), rows * d_in);
    for r in 0..rows {
        let dyr = &dy[r * d_out..(r + 1) * d_out];
        let dxr = &mut dx[r * d_in..(r + 1) * d_in];
        for (i, out) in dxr.iter_mut().enumerate() {
            *out = dot(dyr, &w[i * d_out..(i + 1) * d_out]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal(0.0, 0.5) as f32).collect()
    }

    /// Naive f64 references for every kernel.
    fn ref_gemm_bias(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        rows: usize,
        d_in: usize,
        d_out: usize,
    ) -> Vec<f64> {
        let mut y = vec![0.0f64; rows * d_out];
        for r in 0..rows {
            for j in 0..d_out {
                let mut acc = b[j] as f64;
                for i in 0..d_in {
                    acc += x[r * d_in + i] as f64 * w[i * d_out + j] as f64;
                }
                y[r * d_out + j] = acc;
            }
        }
        y
    }

    #[test]
    fn gemm_bias_matches_f64_reference() {
        let mut rng = Rng::new(41);
        let (rows, d_in, d_out) = (7, 10, 13);
        let x = randv(&mut rng, rows * d_in);
        let w = randv(&mut rng, d_in * d_out);
        let b = randv(&mut rng, d_out);
        let mut y = vec![0.0f32; rows * d_out];
        gemm_bias(&x, &w, &b, &mut y, rows, d_in, d_out);
        let want = ref_gemm_bias(&x, &w, &b, rows, d_in, d_out);
        for (g, w) in y.iter().zip(want.iter()) {
            assert!((*g as f64 - w).abs() < 1e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn gemm_single_row_bit_identical_to_linear() {
        let mut rng = Rng::new(42);
        let (d_in, d_out) = (10, 64);
        let x = randv(&mut rng, d_in);
        let w = randv(&mut rng, d_in * d_out);
        let b = randv(&mut rng, d_out);
        let mut y_row = vec![0.0f32; d_out];
        linear(&x, &w, &b, &mut y_row);
        let mut y_gemm = vec![0.0f32; d_out];
        gemm_bias(&x, &w, &b, &mut y_gemm, 1, d_in, d_out);
        assert!(
            y_row.iter().zip(y_gemm.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
            "batched kernel must be bit-identical to the row kernel"
        );
    }

    #[test]
    fn grad_weights_matches_f64_reference() {
        let mut rng = Rng::new(43);
        let (rows, d_in, d_out) = (9, 6, 11);
        let x = randv(&mut rng, rows * d_in);
        let dy = randv(&mut rng, rows * d_out);
        let mut gw = vec![1.0f32; d_in * d_out]; // must be overwritten
        grad_weights(&x, &dy, &mut gw, rows, d_in, d_out);
        for i in 0..d_in {
            for j in 0..d_out {
                let mut acc = 0.0f64;
                for r in 0..rows {
                    acc += x[r * d_in + i] as f64 * dy[r * d_out + j] as f64;
                }
                let got = gw[i * d_out + j] as f64;
                assert!((got - acc).abs() < 1e-4, "gw[{i},{j}] {got} vs {acc}");
            }
        }
    }

    #[test]
    fn grad_bias_matches_column_sums() {
        let mut rng = Rng::new(44);
        let (rows, d_out) = (8, 5);
        let dy = randv(&mut rng, rows * d_out);
        let mut gb = vec![9.0f32; d_out];
        grad_bias(&dy, &mut gb, rows, d_out);
        for j in 0..d_out {
            let want: f64 = (0..rows).map(|r| dy[r * d_out + j] as f64).sum();
            assert!((gb[j] as f64 - want).abs() < 1e-5);
        }
    }

    #[test]
    fn gemm_wt_matches_f64_reference() {
        let mut rng = Rng::new(45);
        let (rows, d_in, d_out) = (6, 12, 7);
        let dy = randv(&mut rng, rows * d_out);
        let w = randv(&mut rng, d_in * d_out);
        let mut dx = vec![0.0f32; rows * d_in];
        gemm_wt(&dy, &w, &mut dx, rows, d_in, d_out);
        for r in 0..rows {
            for i in 0..d_in {
                let mut acc = 0.0f64;
                for j in 0..d_out {
                    acc += dy[r * d_out + j] as f64 * w[i * d_out + j] as f64;
                }
                let got = dx[r * d_in + i] as f64;
                assert!((got - acc).abs() < 1e-4, "dx[{r},{i}] {got} vs {acc}");
            }
        }
    }

    #[test]
    fn dot_handles_remainders() {
        for n in [0usize, 1, 3, 4, 5, 8, 13] {
            let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let b = vec![2.0f32; n];
            let want: f32 = (0..n).map(|i| 2.0 * i as f32).sum();
            assert_eq!(dot(&a, &b), want, "n={n}");
        }
    }

    #[test]
    fn relu_clamps_in_place() {
        let mut y = vec![-1.0f32, 0.0, 2.5, -0.0];
        relu(&mut y);
        assert_eq!(y, vec![0.0, 0.0, 2.5, -0.0]);
    }
}
