//! Minimal JSON parser + writer (RFC 8259 subset: no \u surrogate pairs in
//! the writer, numbers are f64). Used to read `artifacts/manifest.json`
//! and to export experiment results.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use BTreeMap for deterministic output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Builder helpers for result export.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// A number, or `null` when it is not finite — the writer prints
    /// `Json::Num(f64::NAN)` as bare `NaN`, which no parser accepts.
    pub fn num_or_null(x: f64) -> Json {
        if x.is_finite() {
            Json::Num(x)
        } else {
            Json::Null
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn escape_into(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(&mut s, self);
        f.write_str(&s)
    }
}

fn write_json(out: &mut String, v: &Json) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape_into(out, s),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(out, x);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(out, k);
                out.push(':');
                write_json(out, x);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let src = r#"{
          "state_dim": 10,
          "hidden": [64, 64],
          "gamma": 0.99,
          "param_keys": ["w1", "b1"],
          "nested": {"a": true, "b": null}
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("state_dim").unwrap().as_usize(), Some(10));
        assert_eq!(j.get("hidden").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.get("gamma").unwrap().as_f64(), Some(0.99));
        assert_eq!(j.get("nested").unwrap().get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":false}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_control_chars() {
        let j = Json::Str("a\x01b".to_string());
        assert_eq!(j.to_string(), "\"a\\u0001b\"");
    }

    #[test]
    fn numbers_exponents() {
        let j = Json::parse("[1e3, -2.5E-2]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1000.0));
        assert_eq!(a[1].as_f64(), Some(-0.025));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }
}
