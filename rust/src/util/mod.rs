//! From-scratch utility substrates.
//!
//! The offline crate set available in this environment lacks `rand`,
//! `serde`, `clap`, `csv`, `criterion` and `proptest`, so this module
//! implements the minimal production-grade equivalents the rest of the
//! system needs. Each submodule is independently unit-tested.

pub mod bench;
pub mod cli;
pub mod config;
pub mod csv;
pub mod gemm;
pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod stats;
