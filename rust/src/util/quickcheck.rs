//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Runs a property over `n` random cases generated from a seeded [`Rng`];
//! on failure it reports the case index and the *seed that regenerates the
//! failing input*, so failures are reproducible with zero shrinking
//! machinery. Property tests on coordinator/simulator invariants live in
//! `rust/tests/property_*.rs` and build on this.
//!
//! ```ignore
//! // (doctests don't inherit the xla rpath link flags; this exact code
//! // runs as a unit test below)
//! use lace_rl::util::quickcheck::forall;
//! forall("sort is idempotent", 200, 42, |rng| {
//!     let mut v: Vec<u64> = (0..rng.index(50)).map(|_| rng.below(1000)).collect();
//!     v.sort();
//!     let w = { let mut w = v.clone(); w.sort(); w };
//!     if v == w { Ok(()) } else { Err("double sort differs".into()) }
//! });
//! ```

use crate::util::rng::Rng;

/// Run `prop` over `cases` random inputs. Each case gets a fresh `Rng`
/// derived from (`seed`, case index) so any failure is reproducible in
/// isolation. Panics with a diagnostic on the first failing case.
pub fn forall<F>(name: &str, cases: u64, seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = case_rng(seed, case);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (reproduce with seed={seed}, case={case}): {msg}"
            );
        }
    }
}

/// The deterministic per-case generator `forall` uses; exposed so a failing
/// case can be replayed in a debugger.
pub fn case_rng(seed: u64, case: u64) -> Rng {
    Rng::new(seed ^ case.wrapping_mul(0xA076_1D64_78BD_642F))
}

/// Assert-style helper for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("u64 below bound", 100, 1, |rng| {
            let n = 1 + rng.below(100);
            let x = rng.below(n);
            if x < n {
                Ok(())
            } else {
                Err(format!("{x} >= {n}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failures() {
        forall("always fails", 10, 2, |_| Err("nope".into()));
    }

    #[test]
    fn case_rng_is_deterministic() {
        let mut a = case_rng(5, 3);
        let mut b = case_rng(5, 3);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = case_rng(5, 4);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn prop_assert_macro() {
        forall("macro works", 10, 3, |rng| {
            let x = rng.f64();
            crate::prop_assert!(x < 1.0, "x={x} out of range");
            Ok(())
        });
    }
}
