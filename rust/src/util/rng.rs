//! Deterministic PRNG + sampling distributions.
//!
//! Core generator is xoshiro256++ (Blackman/Vigna) seeded through SplitMix64,
//! which passes BigCrush and is fully reproducible across platforms — every
//! experiment in EXPERIMENTS.md pins its seed. Distributions implemented on
//! top: uniform, normal (Ziggurat-free Box–Muller with cache), exponential,
//! lognormal, Pareto, Poisson, categorical/Zipf.

/// xoshiro256++ PRNG. `Clone` lets policies fork deterministic substreams.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of Box–Muller.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator deterministically.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent substream (e.g. one per function profile).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Stateless substream derivation: a generator determined only by
    /// (`seed`, `stream`), consuming nothing from a parent. Stochastic
    /// policies key one stream per function id so their decision sequences
    /// depend only on that function's own history — invariant under any
    /// sharding of the trace across threads (`simulator::sharded`).
    pub fn stream(seed: u64, stream: u64) -> Rng {
        Rng::new(seed ^ stream.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (caches the paired output).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Lognormal: exp(N(mu, sigma)). `mu`/`sigma` are the *log-space* params.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Pareto with scale `x_m > 0` and shape `alpha > 0` (heavy tail).
    #[inline]
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        x_m / u.powf(1.0 / alpha)
    }

    /// Poisson-distributed count. Knuth's method for small means, normal
    /// approximation (rounded, clamped at 0) above 30 — adequate for
    /// arrival-count generation.
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        let z = self.normal(mean, mean.sqrt());
        if z < 0.0 {
            0
        } else {
            z.round() as u64
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf-like popularity weights for `n` items with exponent `s`.
    pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
        (1..=n).map(|k| 1.0 / (k as f64).powf(s)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniform random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gauss();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(7);
        for lam in [0.5, 4.0, 50.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < lam.max(1.0) * 0.05, "lam={lam} mean={mean}");
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(8);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "{counts:?}");
    }

    #[test]
    fn pareto_is_heavy_tailed() {
        let mut r = Rng::new(9);
        let xs: Vec<f64> = (0..50_000).map(|_| r.pareto(1.0, 1.5)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0));
        let max = xs.iter().cloned().fold(0.0, f64::max);
        assert!(max > 50.0, "max={max}"); // tail reaches far out
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn stream_is_stateless_and_decorrelated() {
        let mut a = Rng::stream(7, 3);
        let mut b = Rng::stream(7, 3);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::stream(7, 4);
        let same = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
