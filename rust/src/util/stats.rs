//! Descriptive statistics: summaries, percentiles, empirical CDFs and
//! fixed-bin histograms. Used by trace characterization (Fig. 1/3), the
//! state encoder's reuse-probability estimates, and the bench harness.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a *sorted* slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Convenience: percentile of an unsorted slice (clones + sorts).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// Empirical CDF over a sample; supports evaluation and fixed-point dumps
/// for figure regeneration.
#[derive(Debug, Clone)]
pub struct Ecdf {
    sorted: Vec<f64>,
}

impl Ecdf {
    pub fn new(mut xs: Vec<f64>) -> Self {
        xs.retain(|x| x.is_finite());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ecdf { sorted: xs }
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// P[X <= x].
    pub fn eval(&self, x: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        // partition_point = count of elements <= x
        let idx = self.sorted.partition_point(|&v| v <= x);
        idx as f64 / self.sorted.len() as f64
    }

    /// Inverse CDF (quantile), q in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        percentile_sorted(&self.sorted, q * 100.0)
    }

    /// `(x, P[X<=x])` rows at `n` evenly spaced quantiles — figure output.
    pub fn curve(&self, n: usize) -> Vec<(f64, f64)> {
        (0..=n)
            .map(|i| {
                let q = i as f64 / n as f64;
                (self.quantile(q), q)
            })
            .collect()
    }

    pub fn min(&self) -> f64 {
        *self.sorted.first().unwrap_or(&f64::NAN)
    }

    pub fn max(&self) -> f64 {
        *self.sorted.last().unwrap_or(&f64::NAN)
    }
}

/// Fixed-bin histogram over [lo, hi); out-of-range values clamp to the edge
/// bins, mirroring the bounded keep-alive action set.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0 }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let f = ((x - self.lo) / (self.hi - self.lo) * bins as f64).floor();
        let idx = if f < 0.0 {
            0
        } else if f as usize >= bins {
            bins - 1
        } else {
            f as usize
        };
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Fraction of mass at or below bin containing `x`.
    pub fn cdf_at(&self, x: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let bins = self.counts.len();
        let f = ((x - self.lo) / (self.hi - self.lo) * bins as f64).floor();
        let idx = if f < 0.0 {
            return 0.0;
        } else if f as usize >= bins {
            bins - 1
        } else {
            f as usize
        };
        let cum: u64 = self.counts[..=idx].iter().sum();
        cum as f64 / self.total as f64
    }
}

/// Online mean/min/max/count accumulator (no allocation in hot loops).
#[derive(Debug, Clone, Default)]
pub struct Running {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { count: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Fold another accumulator into this one. Addition order is
    /// caller-controlled: folding per-function partials in function-id
    /// order reproduces a sequential accumulation bit-for-bit (the
    /// sharded-simulation merge contract, see `simulator::sharded`).
    pub fn merge(&mut self, other: &Running) {
        self.count += other.count;
        self.sum += other.sum;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ecdf_eval_monotone() {
        let e = Ecdf::new(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(e.eval(0.5), 0.0);
        assert!((e.eval(2.5) - 0.4).abs() < 1e-12);
        assert_eq!(e.eval(5.0), 1.0);
        let mut prev = -1.0;
        for i in 0..60 {
            let v = e.eval(i as f64 * 0.1);
            assert!(v >= prev);
            prev = v;
        }
    }

    #[test]
    fn ecdf_quantile_roundtrip() {
        let e = Ecdf::new((1..=100).map(|i| i as f64).collect());
        assert!((e.quantile(0.5) - 50.5).abs() < 1.0);
        assert_eq!(e.quantile(0.0), 1.0);
        assert_eq!(e.quantile(1.0), 100.0);
    }

    #[test]
    fn ecdf_drops_non_finite() {
        let e = Ecdf::new(vec![1.0, f64::NAN, 2.0, f64::INFINITY]);
        assert_eq!(e.len(), 2);
    }

    #[test]
    fn histogram_clamps_and_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(-5.0);
        h.add(0.5);
        h.add(9.99);
        h.add(42.0);
        assert_eq!(h.total(), 4);
        assert_eq!(h.counts()[0], 2);
        assert_eq!(h.counts()[9], 2);
        assert!((h.cdf_at(1.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn running_merge_matches_sequential_adds() {
        let xs = [3.0, -1.0, 7.0, 2.5, 0.0, 9.5];
        let mut whole = Running::new();
        for &x in &xs {
            whole.add(x);
        }
        let (mut a, mut b) = (Running::new(), Running::new());
        for &x in &xs[..3] {
            a.add(x);
        }
        for &x in &xs[3..] {
            b.add(x);
        }
        a.merge(&b);
        assert_eq!(a.count, whole.count);
        assert_eq!(a.sum.to_bits(), whole.sum.to_bits());
        assert_eq!(a.min, whole.min);
        assert_eq!(a.max, whole.max);
        // Merging an empty accumulator is a no-op.
        let before = a.clone();
        a.merge(&Running::new());
        assert_eq!(a.sum.to_bits(), before.sum.to_bits());
        assert_eq!(a.min, before.min);
    }

    #[test]
    fn running_tracks_extremes() {
        let mut r = Running::new();
        for x in [3.0, -1.0, 7.0] {
            r.add(x);
        }
        assert_eq!(r.count, 3);
        assert_eq!(r.min, -1.0);
        assert_eq!(r.max, 7.0);
        assert!((r.mean() - 3.0).abs() < 1e-12);
    }
}
