//! Proof that a native gradient step performs **zero heap allocations**:
//! a counting global allocator wraps `System`, and after one warm-up step
//! the allocation counter must not move across 100 further steps
//! (including target syncs).
//!
//! This test owns its binary: `#[global_allocator]` is process-wide, and
//! sharing the binary with unrelated tests would race the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use lace_rl::rl::backend::TrainBackend;
use lace_rl::rl::native_train::NativeBackend;
use lace_rl::rl::qnet::QNetParams;
use lace_rl::rl::replay::SampleBatch;
use lace_rl::rl::trainer::default_dims;
use lace_rl::util::rng::Rng;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; the counter is a relaxed
// atomic side effect with no bearing on the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn synthetic_batch(rng: &mut Rng, batch: usize, n_actions: usize) -> SampleBatch {
    let mut sb = SampleBatch::new(batch);
    for x in sb.states.iter_mut() {
        *x = rng.f64() as f32;
    }
    for x in sb.next_states.iter_mut() {
        *x = rng.f64() as f32;
    }
    for a in sb.actions.iter_mut() {
        *a = rng.index(n_actions) as i32;
    }
    for r in sb.rewards.iter_mut() {
        *r = -(rng.f64() as f32);
    }
    for d in sb.dones.iter_mut() {
        *d = if rng.chance(0.2) { 1.0 } else { 0.0 };
    }
    sb
}

#[test]
fn native_gradient_step_is_allocation_free() {
    let dims = default_dims();
    let batch = 64;
    let mut backend = NativeBackend::new(QNetParams::he_uniform(dims, 3), batch);
    let mut rng = Rng::new(9);
    let batches: Vec<SampleBatch> =
        (0..8).map(|_| synthetic_batch(&mut rng, batch, dims.3)).collect();

    // Warm up once (lazy one-time init anywhere in the path is fine; the
    // steady state must not allocate).
    backend.step(1, &batches[0]).unwrap();
    backend.sync_target();

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for t in 2..=101u64 {
        let sb = &batches[t as usize % batches.len()];
        let loss = backend.step(t, sb).unwrap();
        assert!(loss.is_finite());
        if t % 20 == 0 {
            backend.sync_target();
        }
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "native gradient steps performed {} heap allocations over 100 steps",
        after - before
    );
}
