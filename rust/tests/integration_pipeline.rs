//! End-to-end pipeline integration: train (via AOT PJRT) → evaluate →
//! the trained policy must beat untrained on the training distribution;
//! plus experiment-harness smoke tests.

use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::energy::model::EnergyModel;
use lace_rl::experiments;
use lace_rl::experiments::workload::evaluate;
use lace_rl::policy::lace_rl::LaceRlPolicy;
use lace_rl::policy::native_mlp::NativeMlp;
use lace_rl::policy::{blended_cost, FixedTimeout};
use lace_rl::rl::trainer::{train, TrainerConfig};
use lace_rl::runtime::{artifacts, ArtifactSet, PjrtRuntime};
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};

fn artifacts_available() -> bool {
    std::path::Path::new(&artifacts::default_dir())
        .join("manifest.json")
        .exists()
}

#[test]
fn train_then_evaluate_beats_init_weights() {
    if !artifacts_available() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let art = ArtifactSet::open(&artifacts::default_dir()).unwrap();
    let rt = PjrtRuntime::cpu().unwrap();

    let trace = TraceGenerator::new(SynthConfig {
        n_functions: 50,
        duration_s: 3_600.0,
        target_invocations: 30_000,
        seed: 99,
        ..SynthConfig::default()
    })
    .generate();
    let (train_trace, _, test_trace) = trace.split(0.8, 0.1);
    let ci = synth_region(Region::SolarHeavy, 1, 99);
    let energy = EnergyModel::default();

    let lambda = 0.5;
    let cfg = TrainerConfig {
        episodes: 20,
        steps_per_episode: 500,
        epsilon_decay: 0.8, // reach near-greedy rollouts within the budget
        lambda_carbon: Some(lambda),
        verbose: false,
        seed: 99,
        ..TrainerConfig::default()
    };
    let report = train(&art, &rt, &train_trace, &ci, &energy, &cfg).unwrap();
    assert!(report.total_steps > 0);

    let blended = |m: &lace_rl::simulator::metrics::SimMetrics| {
        // Realized aggregate Eq. 5 objective: cold-start latency-seconds
        // plus carbon-priced keep-alive grams (the units the reward uses).
        blended_cost(lambda, m.cold_latency_s, m.keepalive_carbon_g)
    };

    let mut trained = LaceRlPolicy::new(NativeMlp::new(report.params.clone()));
    let m_trained = evaluate(&test_trace, &ci, &energy, &mut trained, lambda, false);
    let mut init = LaceRlPolicy::new(NativeMlp::new(art.init_params().unwrap()));
    let m_init = evaluate(&test_trace, &ci, &energy, &mut init, lambda, false);

    // The trained policy must improve the blended objective vs the random
    // init (generous margin — this is a smoke-scale training run and the
    // He-init argmax can be accidentally competitive).
    assert!(
        blended(&m_trained) <= blended(&m_init) * 1.25,
        "training regressed the objective: {} vs init {}",
        blended(&m_trained),
        blended(&m_init)
    );

    // And it must not be degenerate: some pods are kept, some dropped.
    assert!(m_trained.cold_starts > 0);
    assert!(m_trained.keepalive_carbon_g > 0.0);
}

#[test]
fn trained_policy_beats_huawei_on_lcp() {
    // Uses the repo's trained weights (if present) on a fresh workload —
    // the headline Fig. 5/7 claim in miniature.
    if !artifacts_available() {
        eprintln!("artifacts not built; skipping");
        return;
    }
    let art = ArtifactSet::open(&artifacts::default_dir()).unwrap();
    if !art.trained_weights_path().exists() {
        eprintln!("no trained weights (run `lace-rl train`); skipping");
        return;
    }
    let trace = TraceGenerator::new(SynthConfig {
        n_functions: 80,
        duration_s: 7_200.0,
        target_invocations: 40_000,
        seed: 1234, // unseen during training
        ..SynthConfig::default()
    })
    .generate();
    let ci = synth_region(Region::SolarHeavy, 1, 1234);
    let energy = EnergyModel::default();

    let mut lace = LaceRlPolicy::new(NativeMlp::new(art.best_params().unwrap()));
    let m_lace = evaluate(&trace, &ci, &energy, &mut lace, 0.5, false);
    let mut hw = FixedTimeout::huawei();
    let m_hw = evaluate(&trace, &ci, &energy, &mut hw, 0.5, false);

    assert!(
        m_lace.lcp() < m_hw.lcp(),
        "LACE-RL LCP {} should beat Huawei {}",
        m_lace.lcp(),
        m_hw.lcp()
    );
    assert!(
        m_lace.keepalive_carbon_g < m_hw.keepalive_carbon_g,
        "LACE-RL keep-alive carbon should beat the static 60s window"
    );
}

#[test]
fn experiment_smoke_table2() {
    experiments::run("table2", 7, true).unwrap();
}

#[test]
fn experiment_smoke_fig3() {
    experiments::run("fig3", 7, true).unwrap();
}
