//! Integration: the AOT artifact chain (Pallas/jax → HLO text → PJRT)
//! against the native Rust implementations. Requires `make artifacts`.

use lace_rl::policy::native_mlp::NativeMlp;
use lace_rl::rl::qnet::QNetParams;
use lace_rl::runtime::{artifacts, ArtifactSet, PjrtRuntime, QNetInfer, TrainStep};
use lace_rl::util::rng::Rng;

fn open() -> Option<(ArtifactSet, PjrtRuntime)> {
    let dir = artifacts::default_dir();
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping PJRT integration tests");
        return None;
    }
    let art = ArtifactSet::open(&dir).expect("artifact set");
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    Some((art, rt))
}

fn random_states(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.f64() as f32).collect()
}

#[test]
fn pallas_infer_b1_matches_native() {
    let Some((art, rt)) = open() else { return };
    let params = art.init_params().unwrap();
    let dims = art.manifest.dims();
    let infer = QNetInfer::new(
        rt.load_hlo_text(art.infer_path(1).to_str().unwrap()).unwrap(),
        1,
        dims,
    );
    let mut native = NativeMlp::new(params.clone());
    let mut rng = Rng::new(1);
    for _ in 0..20 {
        let state = random_states(&mut rng, dims.0);
        let q_pjrt = infer.q_values(&params, &state).unwrap();
        let q_native = native.forward(&state);
        for (a, b) in q_pjrt.iter().zip(q_native.iter()) {
            assert!((a - b).abs() < 1e-4, "pjrt {a} vs native {b}");
        }
    }
}

#[test]
fn pallas_infer_b256_matches_native() {
    let Some((art, rt)) = open() else { return };
    let params = art.init_params().unwrap();
    let dims = art.manifest.dims();
    let infer = QNetInfer::new(
        rt.load_hlo_text(art.infer_path(256).to_str().unwrap()).unwrap(),
        256,
        dims,
    );
    let mut rng = Rng::new(2);
    let states = random_states(&mut rng, 256 * dims.0);
    let q = infer.q_values(&params, &states).unwrap();
    let mut native = NativeMlp::new(params.clone());
    for b in [0usize, 17, 255] {
        let qs = &q[b * dims.3..(b + 1) * dims.3];
        let qn = native.forward(&states[b * dims.0..(b + 1) * dims.0]);
        for (a, n) in qs.iter().zip(qn.iter()) {
            assert!((a - n).abs() < 1e-4);
        }
    }
}

#[test]
fn pallas_and_jnp_artifacts_agree() {
    let Some((art, rt)) = open() else { return };
    let params = art.init_params().unwrap();
    let dims = art.manifest.dims();
    let pallas = QNetInfer::new(
        rt.load_hlo_text(art.infer_path(1).to_str().unwrap()).unwrap(),
        1,
        dims,
    );
    let jnp = QNetInfer::new(
        rt.load_hlo_text(art.infer_jnp_path(1).to_str().unwrap()).unwrap(),
        1,
        dims,
    );
    let mut rng = Rng::new(3);
    for _ in 0..10 {
        let state = random_states(&mut rng, dims.0);
        let qa = pallas.q_values(&params, &state).unwrap();
        let qb = jnp.q_values(&params, &state).unwrap();
        for (a, b) in qa.iter().zip(qb.iter()) {
            assert!((a - b).abs() < 1e-5, "pallas {a} vs jnp {b}");
        }
    }
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    let Some((art, rt)) = open() else { return };
    let dims = art.manifest.dims();
    let b = art.manifest.train_batch;
    let step = TrainStep::new(
        rt.load_hlo_text(art.train_step_path().to_str().unwrap()).unwrap(),
        b,
        dims,
    );
    let mut params = art.init_params().unwrap();
    let target = params.clone();
    let mut m = QNetParams::zeros(dims);
    let mut v = QNetParams::zeros(dims);
    let mut rng = Rng::new(4);
    let states = random_states(&mut rng, b * dims.0);
    let next_states = random_states(&mut rng, b * dims.0);
    let actions: Vec<i32> = (0..b).map(|_| rng.index(dims.3) as i32).collect();
    let rewards: Vec<f32> = (0..b).map(|_| -(rng.f64() as f32)).collect();
    let dones: Vec<f32> = (0..b).map(|_| if rng.chance(0.3) { 1.0 } else { 0.0 }).collect();

    let mut losses = Vec::new();
    for t in 1..=40 {
        let out = step
            .step(&params, &target, &m, &v, t as f32, &states, &actions, &rewards, &next_states, &dones)
            .unwrap();
        params = out.params;
        m = out.m;
        v = out.v;
        losses.push(out.loss);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.5),
        "loss did not halve: {:?}",
        &losses[..5]
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn train_step_is_deterministic() {
    let Some((art, rt)) = open() else { return };
    let dims = art.manifest.dims();
    let b = art.manifest.train_batch;
    let step = TrainStep::new(
        rt.load_hlo_text(art.train_step_path().to_str().unwrap()).unwrap(),
        b,
        dims,
    );
    let params = art.init_params().unwrap();
    let zero = QNetParams::zeros(dims);
    let states = vec![0.25f32; b * dims.0];
    let actions = vec![1i32; b];
    let rewards = vec![-0.5f32; b];
    let dones = vec![0.0f32; b];
    let o1 = step
        .step(&params, &params, &zero, &zero, 1.0, &states, &actions, &rewards, &states, &dones)
        .unwrap();
    let o2 = step
        .step(&params, &params, &zero, &zero, 1.0, &states, &actions, &rewards, &states, &dones)
        .unwrap();
    assert_eq!(o1.loss, o2.loss);
    assert_eq!(o1.params.max_abs_diff(&o2.params), 0.0);
}

#[test]
fn train_step_gradient_direction_sane() {
    // With targets strictly below current Q for action a, the step must
    // decrease Q(s, a) (gradient descent on (q_sel - target)^2).
    let Some((art, rt)) = open() else { return };
    let dims = art.manifest.dims();
    let b = art.manifest.train_batch;
    let step = TrainStep::new(
        rt.load_hlo_text(art.train_step_path().to_str().unwrap()).unwrap(),
        b,
        dims,
    );
    let params = art.init_params().unwrap();
    let zero = QNetParams::zeros(dims);
    let state = vec![0.5f32; dims.0];
    let states: Vec<f32> = state.repeat(b);
    let actions = vec![2i32; b];
    let rewards = vec![-100.0f32; b]; // target far below any Q
    let dones = vec![1.0f32; b]; // target = reward exactly
    let out = step
        .step(&params, &params, &zero, &zero, 1.0, &states, &actions, &rewards, &states, &dones)
        .unwrap();
    let q_before = NativeMlp::new(params.clone()).forward(&state)[2];
    let q_after = NativeMlp::new(out.params).forward(&state)[2];
    assert!(
        q_after < q_before,
        "Q(s,a) should move toward the low target: {q_before} -> {q_after}"
    );
}
