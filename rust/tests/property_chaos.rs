//! Properties of the `chaos` fault-injection subsystem:
//!
//! 1. A `None`/empty plan is byte-identical to a run without the subsystem
//!    — injection disabled really is a no-op.
//! 2. Same plan + same seed ⇒ bit-identical results, across reruns *and*
//!    shard counts (fault draws are pure functions of the event identity).
//! 3. A certain (p = 1) spawn failure exhausts the retry budget on every
//!    cold start — the deterministic anchor for the backoff accounting.
//! 4. A full-trace carbon outage degrades only the decision *inputs*:
//!    carbon accounting still reads the true trace, so a CI-blind policy's
//!    metrics are bitwise unchanged while every decision counts as stale.
//! 5. The online router and the engine agree invocation-by-invocation
//!    under the same plan.

use std::sync::Arc;

use lace_rl::carbon::intensity::CarbonTrace;
use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::chaos::{ChaosInjector, Fault, FaultPlan, RecoveryConfig};
use lace_rl::coordinator::{InvocationRequest, Router, RouterConfig};
use lace_rl::energy::model::EnergyModel;
use lace_rl::policy::dpso::{Dpso, DpsoConfig};
use lace_rl::policy::{BoxedPolicy, CarbonMin, FixedTimeout, LatencyMin};
use lace_rl::prop_assert;
use lace_rl::simulator::engine::{SimConfig, Simulator};
use lace_rl::simulator::sharded::ShardedSimulator;
use lace_rl::trace::model::Trace;
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::util::quickcheck::forall;
use lace_rl::util::rng::Rng;

fn small_trace(rng: &mut Rng) -> Trace {
    let cfg = SynthConfig {
        n_functions: 8 + rng.index(20),
        duration_s: 600.0 + rng.f64() * 1200.0,
        target_invocations: 2_000 + rng.index(3_000),
        seed: rng.next_u64(),
        ..SynthConfig::default()
    };
    TraceGenerator::new(cfg).generate()
}

fn random_ci(rng: &mut Rng) -> CarbonTrace {
    match rng.index(2) {
        0 => CarbonTrace::constant(100.0 + rng.f64() * 600.0),
        _ => synth_region(Region::SolarHeavy, 1, rng.next_u64()),
    }
}

fn policy_grid() -> Vec<(&'static str, Box<dyn Fn() -> BoxedPolicy>)> {
    vec![
        ("huawei-60s", Box::new(|| Box::new(FixedTimeout::huawei()) as BoxedPolicy)),
        ("latency-min", Box::new(|| Box::new(LatencyMin) as BoxedPolicy)),
        ("carbon-min", Box::new(|| Box::new(CarbonMin) as BoxedPolicy)),
        (
            "dpso-ecolife",
            Box::new(|| Box::new(Dpso::new(DpsoConfig::default())) as BoxedPolicy),
        ),
    ]
}

fn span_of(trace: &Trace) -> (f64, f64) {
    let t0 = trace.invocations.first().map(|i| i.t).unwrap_or(0.0);
    let t1 = trace.invocations.last().map(|i| i.t).unwrap_or(t0);
    (t0, t1)
}

/// Bitwise comparison of the non-chaos metric fields of two runs.
fn assert_metrics_bitwise(
    name: &str,
    a: &lace_rl::simulator::metrics::SimMetrics,
    b: &lace_rl::simulator::metrics::SimMetrics,
) -> Result<(), String> {
    lace_rl::prop_assert!(
        a.invocations == b.invocations
            && a.cold_starts == b.cold_starts
            && a.warm_starts == b.warm_starts,
        "{name}: counts diverge"
    );
    for (field, x, y) in [
        ("keepalive_carbon_g", a.keepalive_carbon_g, b.keepalive_carbon_g),
        ("exec_carbon_g", a.exec_carbon_g, b.exec_carbon_g),
        ("cold_carbon_g", a.cold_carbon_g, b.cold_carbon_g),
        ("cold_latency_s", a.cold_latency_s, b.cold_latency_s),
        ("latency_sum", a.latency.sum, b.latency.sum),
        ("idle_pod_seconds", a.idle_pod_seconds, b.idle_pod_seconds),
        ("wasted_idle_seconds", a.wasted_idle_seconds, b.wasted_idle_seconds),
    ] {
        lace_rl::prop_assert!(
            x.to_bits() == y.to_bits(),
            "{name}: {field} diverges: {x:e} vs {y:e}"
        );
    }
    Ok(())
}

#[test]
fn disabled_plan_is_byte_identical_to_no_injector() {
    forall("empty plan == no injector", 4, 281, |rng| {
        let trace = small_trace(rng);
        let ci = random_ci(rng);
        let energy = EnergyModel::default();
        for (name, factory) in policy_grid() {
            let base = SimConfig { track_latencies: true, ..SimConfig::default() };
            let with_empty = SimConfig {
                chaos: Some(Arc::new(ChaosInjector::new(FaultPlan::empty(
                    rng.next_u64(),
                )))),
                ..base.clone()
            };
            let mut p = factory();
            let off = Simulator::new(&trace, &ci, energy.clone(), base).run(p.as_mut());
            let mut p = factory();
            let on =
                Simulator::new(&trace, &ci, energy.clone(), with_empty).run(p.as_mut());
            assert_metrics_bitwise(name, &off.metrics, &on.metrics)?;
            prop_assert!(
                !on.metrics.chaos.any(),
                "{name}: empty plan recorded chaos events"
            );
            prop_assert!(
                off.latencies.len() == on.latencies.len()
                    && off
                        .latencies
                        .iter()
                        .zip(on.latencies.iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                "{name}: latencies changed by an empty plan"
            );
        }
        Ok(())
    });
}

#[test]
fn same_plan_is_deterministic_and_shard_invariant() {
    forall("same plan + seed => bit-identical", 3, 282, |rng| {
        let trace = small_trace(rng);
        let ci = random_ci(rng);
        let energy = EnergyModel::default();
        let (t0, t1) = span_of(&trace);
        let intensity = *rng.choice(&[0.3, 0.7, 1.0]);
        let plan = FaultPlan::canned(rng.next_u64(), t0, t1, intensity);
        let cfg = SimConfig {
            chaos: Some(Arc::new(ChaosInjector::new(plan))),
            track_latencies: true,
            ..SimConfig::default()
        };
        for (name, factory) in policy_grid() {
            let mut p = factory();
            let a = Simulator::new(&trace, &ci, energy.clone(), cfg.clone()).run(p.as_mut());
            let mut p = factory();
            let b = Simulator::new(&trace, &ci, energy.clone(), cfg.clone()).run(p.as_mut());
            assert_metrics_bitwise(name, &a.metrics, &b.metrics)?;
            prop_assert!(
                a.metrics.chaos == b.metrics.chaos,
                "{name}: chaos counters not reproducible: {:?} vs {:?}",
                a.metrics.chaos,
                b.metrics.chaos
            );
            prop_assert!(
                a.latencies
                    .iter()
                    .zip(b.latencies.iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "{name}: latencies not reproducible"
            );
            for k in [2usize, 5] {
                let mut p = factory();
                let sh = ShardedSimulator::new(&trace, &ci, energy.clone(), cfg.clone())
                    .with_shards(k)
                    .run(p.as_mut());
                assert_metrics_bitwise(&format!("{name} k={k}"), &a.metrics, &sh.metrics)?;
                prop_assert!(
                    sh.metrics.chaos == a.metrics.chaos,
                    "{name} k={k}: sharded chaos counters drifted"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn certain_spawn_failure_exhausts_the_retry_budget() {
    forall("p=1 spawn failure exhausts retries", 4, 283, |rng| {
        let trace = small_trace(rng);
        let ci = random_ci(rng);
        let (_, t1) = span_of(&trace);
        let rc = RecoveryConfig::default();
        let plan = FaultPlan {
            seed: rng.next_u64(),
            faults: vec![Fault::SpawnFailure { from_s: 0.0, until_s: t1 + 1.0, p: 1.0 }],
            recovery: rc,
        };
        let cfg = SimConfig {
            chaos: Some(Arc::new(ChaosInjector::new(plan))),
            ..SimConfig::default()
        };
        let r = Simulator::new(&trace, &ci, EnergyModel::default(), cfg)
            .run(&mut FixedTimeout::huawei());
        let want = r.metrics.cold_starts * u64::from(rc.max_spawn_retries);
        prop_assert!(
            r.metrics.chaos.spawn_retries == want,
            "spawn_retries {} != cold_starts {} x budget {}",
            r.metrics.chaos.spawn_retries,
            r.metrics.cold_starts,
            rc.max_spawn_retries
        );
        prop_assert!(
            r.metrics.chaos.retry_delay_s > 0.0,
            "no backoff delay despite {} retries",
            r.metrics.chaos.spawn_retries
        );
        Ok(())
    });
}

#[test]
fn full_outage_degrades_only_decision_inputs() {
    forall("outage is accounting-neutral for CI-blind policies", 4, 284, |rng| {
        let trace = small_trace(rng);
        let ci = random_ci(rng);
        let energy = EnergyModel::default();
        let (t0, t1) = span_of(&trace);
        let plan = FaultPlan {
            seed: rng.next_u64(),
            // Generously past the last completion so every decision is
            // inside the outage.
            faults: vec![Fault::CarbonOutage { from_s: t0, until_s: t1 + 10_000.0 }],
            recovery: RecoveryConfig::default(),
        };
        let chaos_cfg = SimConfig {
            chaos: Some(Arc::new(ChaosInjector::new(plan))),
            ..SimConfig::default()
        };
        // Huawei's fixed timeout never reads ctx.ci, so the stale fallback
        // cannot change its decisions — all non-chaos metrics must match
        // the fault-free run bit-for-bit.
        let base = Simulator::new(&trace, &ci, energy.clone(), SimConfig::default())
            .run(&mut FixedTimeout::huawei());
        let faulted = Simulator::new(&trace, &ci, energy.clone(), chaos_cfg)
            .run(&mut FixedTimeout::huawei());
        assert_metrics_bitwise("huawei-60s", &base.metrics, &faulted.metrics)?;
        prop_assert!(
            faulted.metrics.chaos.stale_ci_decisions == faulted.metrics.invocations,
            "stale decisions {} != invocations {}",
            faulted.metrics.chaos.stale_ci_decisions,
            faulted.metrics.invocations
        );
        prop_assert!(
            faulted.metrics.chaos.spawn_retries == 0
                && faulted.metrics.chaos.degraded_decisions == 0,
            "outage-only plan triggered other fault classes"
        );
        Ok(())
    });
}

#[test]
fn router_matches_engine_under_the_same_plan() {
    forall("router == engine under chaos", 3, 285, |rng| {
        let trace = small_trace(rng);
        let ci = random_ci(rng);
        let energy = EnergyModel::default();
        let (t0, t1) = span_of(&trace);
        let plan = FaultPlan::canned(rng.next_u64(), t0, t1, 1.0);
        let inj = Arc::new(ChaosInjector::new(plan));

        let sim_cfg = SimConfig {
            chaos: Some(inj.clone()),
            track_latencies: true,
            ..SimConfig::default()
        };
        let sim = Simulator::new(&trace, &ci, energy.clone(), sim_cfg)
            .run(&mut FixedTimeout::huawei());

        let router_cfg = RouterConfig { chaos: Some(inj), ..RouterConfig::default() };
        let mut router = Router::new(
            trace.functions.clone(),
            FixedTimeout::huawei(),
            ci.clone(),
            energy,
            router_cfg,
        );
        let mut latencies = Vec::with_capacity(trace.invocations.len());
        for (id, inv) in trace.invocations.iter().enumerate() {
            let resp = router.handle(&InvocationRequest {
                id: id as u64,
                t: inv.t,
                func: inv.func,
                exec_s: inv.exec_s,
            });
            latencies.push(resp.latency_s);
        }
        let (_, rm) = router.into_parts();
        prop_assert!(
            rm.cold_starts == sim.metrics.cold_starts,
            "cold starts diverge: router {} vs engine {}",
            rm.cold_starts,
            sim.metrics.cold_starts
        );
        prop_assert!(
            latencies.len() == sim.latencies.len()
                && latencies
                    .iter()
                    .zip(sim.latencies.iter())
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
            "per-invocation latencies diverge under chaos"
        );
        // Integer counters match exactly; the f64 backoff total is summed
        // in arrival order online vs function order offline, so compare
        // within rounding slack.
        prop_assert!(
            rm.chaos.spawn_retries == sim.metrics.chaos.spawn_retries
                && rm.chaos.stale_ci_decisions == sim.metrics.chaos.stale_ci_decisions
                && rm.chaos.degraded_decisions == sim.metrics.chaos.degraded_decisions,
            "chaos counters diverge: router {:?} vs engine {:?}",
            rm.chaos,
            sim.metrics.chaos
        );
        let (a, b) = (rm.chaos.retry_delay_s, sim.metrics.chaos.retry_delay_s);
        prop_assert!(
            (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0),
            "retry delay totals diverge: {a} vs {b}"
        );
        Ok(())
    });
}
