//! Property tests on coordinator invariants: routing, lifecycle, and
//! agreement with the offline simulator on randomized workloads.

use lace_rl::carbon::intensity::CarbonTrace;
use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::coordinator::router::{InvocationRequest, Router, RouterConfig};
use lace_rl::energy::model::EnergyModel;
use lace_rl::policy::{CarbonMin, FixedTimeout, LatencyMin};
use lace_rl::prop_assert;
use lace_rl::simulator::engine::{SimConfig, Simulator};
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::util::quickcheck::forall;
use lace_rl::util::rng::Rng;
use lace_rl::KEEP_ALIVE_ACTIONS;

fn random_trace(rng: &mut Rng) -> lace_rl::trace::model::Trace {
    TraceGenerator::new(SynthConfig {
        n_functions: 3 + rng.index(25),
        duration_s: 200.0 + rng.f64() * 2_000.0,
        target_invocations: 300 + rng.index(3_000),
        bursty_frac: rng.f64() * 0.5,
        periodic_frac: rng.f64() * 0.3,
        diurnal: rng.chance(0.5),
        gap_median_s: 2.0 + rng.f64() * 20.0,
        gap_sigma: 1.0 + rng.f64(),
        sparse_frac: rng.f64() * 0.4,
        sparse_gap_median_s: 120.0 + rng.f64() * 600.0,
        seed: rng.next_u64(),
    })
    .generate()
}

fn to_requests(trace: &lace_rl::trace::model::Trace) -> Vec<InvocationRequest> {
    trace
        .invocations
        .iter()
        .enumerate()
        .map(|(id, inv)| InvocationRequest {
            id: id as u64,
            t: inv.t,
            func: inv.func,
            exec_s: inv.exec_s,
        })
        .collect()
}

#[test]
fn router_answers_every_request_in_order() {
    forall("router completeness", 20, 201, |rng| {
        let trace = random_trace(rng);
        let mut router = Router::new(
            trace.functions.clone(),
            FixedTimeout::new(*rng.choice(&KEEP_ALIVE_ACTIONS)),
            CarbonTrace::constant(300.0),
            EnergyModel::default(),
            RouterConfig::default(),
        );
        let reqs = to_requests(&trace);
        let mut last_id = None;
        for req in &reqs {
            let resp = router.handle(req);
            prop_assert!(resp.id == req.id, "response id mismatch");
            prop_assert!(
                last_id.map(|l: u64| resp.id == l + 1).unwrap_or(resp.id == 0),
                "responses out of order"
            );
            last_id = Some(resp.id);
        }
        prop_assert!(
            router.metrics.requests as usize == reqs.len(),
            "request count mismatch"
        );
        Ok(())
    });
}

#[test]
fn first_invocation_of_each_function_is_cold() {
    forall("first is cold", 20, 202, |rng| {
        let trace = random_trace(rng);
        let mut router = Router::new(
            trace.functions.clone(),
            LatencyMin,
            CarbonTrace::constant(300.0),
            EnergyModel::default(),
            RouterConfig::default(),
        );
        let mut seen = vec![false; trace.functions.len()];
        for req in &to_requests(&trace) {
            let resp = router.handle(req);
            if !seen[req.func as usize] {
                prop_assert!(resp.cold, "first invocation of fn {} not cold", req.func);
                seen[req.func as usize] = true;
            }
        }
        Ok(())
    });
}

#[test]
fn keepalive_always_from_policy_range() {
    forall("keepalive bounded", 15, 203, |rng| {
        let trace = random_trace(rng);
        let mut router = Router::new(
            trace.functions.clone(),
            CarbonMin,
            CarbonTrace::constant(300.0),
            EnergyModel::default(),
            RouterConfig::default(),
        );
        for req in &to_requests(&trace) {
            let resp = router.handle(req);
            prop_assert!(
                resp.keepalive_s == KEEP_ALIVE_ACTIONS[0],
                "carbon-min must always pick the minimum action"
            );
            prop_assert!(
                resp.latency_s >= lace_rl::NETWORK_LATENCY_S,
                "latency below network floor"
            );
        }
        Ok(())
    });
}

#[test]
fn router_matches_simulator_exactly() {
    // The online control plane and the offline simulator implement the
    // same semantics: identical cold-start counts, latency sums, and
    // keep-alive carbon on any workload / policy combination.
    forall("router == simulator", 15, 204, |rng| {
        let trace = random_trace(rng);
        let ci = match rng.index(2) {
            0 => CarbonTrace::constant(100.0 + rng.f64() * 600.0),
            _ => synth_region(Region::SolarHeavy, 1, rng.next_u64()),
        };
        let timeout = *rng.choice(&KEEP_ALIVE_ACTIONS);

        let sim = Simulator::new(&trace, &ci, EnergyModel::default(), SimConfig::default());
        let sim_m = sim.run(&mut FixedTimeout::new(timeout)).metrics;

        let mut router = Router::new(
            trace.functions.clone(),
            FixedTimeout::new(timeout),
            ci.clone(),
            EnergyModel::default(),
            RouterConfig::default(),
        );
        for req in &to_requests(&trace) {
            router.handle(req);
        }
        prop_assert!(
            router.metrics.cold_starts == sim_m.cold_starts,
            "cold starts: router {} vs sim {}",
            router.metrics.cold_starts,
            sim_m.cold_starts
        );
        prop_assert!(
            (router.metrics.latency.mean() - sim_m.avg_latency_s()).abs() < 1e-9,
            "latency mismatch"
        );
        // Keep-alive carbon: the router accounts expiries lazily and never
        // flushes at end-of-trace, so it can only under-count vs the
        // simulator (which flushes); reused spans must agree.
        prop_assert!(
            router.metrics.keepalive_carbon_g <= sim_m.keepalive_carbon_g + 1e-9,
            "router idle carbon exceeds simulator's flushed total"
        );
        Ok(())
    });
}

#[test]
fn threaded_and_sync_routers_agree() {
    forall("threaded == sync", 8, 205, |rng| {
        let trace = random_trace(rng);
        let reqs = to_requests(&trace);

        let mut sync_router = Router::new(
            trace.functions.clone(),
            FixedTimeout::new(10.0),
            CarbonTrace::constant(300.0),
            EnergyModel::default(),
            RouterConfig::default(),
        );
        let sync_cold: Vec<bool> = reqs.iter().map(|r| sync_router.handle(r).cold).collect();

        let threaded = Router::new(
            trace.functions.clone(),
            FixedTimeout::new(10.0),
            CarbonTrace::constant(300.0),
            EnergyModel::default(),
            RouterConfig::default(),
        );
        let (req_tx, req_rx) = std::sync::mpsc::channel();
        let (resp_tx, resp_rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || threaded.serve(req_rx, resp_tx));
        for r in &reqs {
            req_tx.send(r.clone()).unwrap();
        }
        drop(req_tx);
        let threaded_cold: Vec<bool> = resp_rx.iter().map(|r| r.cold).collect();
        let _ = h.join().unwrap();

        prop_assert!(sync_cold == threaded_cold, "cold-start sequences differ");
        Ok(())
    });
}
