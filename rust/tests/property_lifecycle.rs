//! Online/offline lifecycle parity: the coordinator's `PodManager` path
//! (`Router::handle`) and the simulator engine make bit-identical warm/cold
//! decisions, charge bit-identical idle spans and carbon, and feed the
//! policy bit-identical decision contexts and outcomes on the same
//! trace + policy. This is the contract that lets serve-mode results stand
//! in for simulated ones (DESIGN.md §6), and it pins the tied-expiry
//! cold-penalty attribution (exactly one charged outcome per cold start)
//! on both stacks.

use lace_rl::carbon::intensity::CarbonTrace;
use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::coordinator::{InvocationRequest, Router, RouterConfig};
use lace_rl::energy::model::EnergyModel;
use lace_rl::policy::{
    CarbonMin, DecisionContext, FixedTimeout, KeepAlivePolicy, LatencyMin, Outcome,
};
use lace_rl::prop_assert;
use lace_rl::simulator::engine::{SimConfig, Simulator};
use lace_rl::trace::model::Trace;
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::util::quickcheck::forall;
use lace_rl::util::rng::Rng;

fn small_trace(rng: &mut Rng) -> Trace {
    let cfg = SynthConfig {
        n_functions: 8 + rng.index(20),
        duration_s: 600.0 + rng.f64() * 1200.0,
        target_invocations: 2_000 + rng.index(3_000),
        seed: rng.next_u64(),
        ..SynthConfig::default()
    };
    TraceGenerator::new(cfg).generate()
}

fn random_ci(rng: &mut Rng) -> CarbonTrace {
    match rng.index(2) {
        0 => CarbonTrace::constant(100.0 + rng.f64() * 600.0),
        _ => synth_region(Region::SolarHeavy, 1, rng.next_u64()),
    }
}

/// Everything the policy is shown at one decision point, as raw bits.
#[derive(Debug, Clone, Copy, PartialEq)]
struct DecideKey {
    t: u64,
    ci: u64,
    reuse_probs: [u64; 5],
    idle_power_w: u64,
    action: usize,
    keep_s: u64,
}

/// Everything a resolved outcome reports, as raw bits.
#[derive(Debug, Clone, Copy, PartialEq)]
struct OutcomeKey {
    func: u32,
    action: usize,
    t: u64,
    resolved_t: u64,
    reused: bool,
    idle_span_s: u64,
    idle_carbon_g: u64,
    cold_penalty_s: u64,
}

/// Recording wrapper: delegates every trait method to the inner policy and
/// logs the decision inputs/outputs and resolved outcomes bit-exactly.
struct Rec<P> {
    inner: P,
    decides: Vec<DecideKey>,
    outcomes: Vec<OutcomeKey>,
}

impl<P> Rec<P> {
    fn new(inner: P) -> Self {
        Rec { inner, decides: Vec::new(), outcomes: Vec::new() }
    }
}

impl<P: KeepAlivePolicy> KeepAlivePolicy for Rec<P> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn decide(&mut self, ctx: &DecisionContext) -> usize {
        self.inner.decide(ctx)
    }

    fn decide_seconds(&mut self, ctx: &DecisionContext) -> (usize, f64) {
        let (action, keep_s) = self.inner.decide_seconds(ctx);
        self.decides.push(DecideKey {
            t: ctx.t.to_bits(),
            ci: ctx.ci.to_bits(),
            reuse_probs: ctx.reuse_probs.map(f64::to_bits),
            idle_power_w: ctx.idle_power_w.to_bits(),
            action,
            keep_s: keep_s.to_bits(),
        });
        (action, keep_s)
    }

    fn refreshes_timer(&self) -> bool {
        self.inner.refreshes_timer()
    }

    fn observe(&mut self, o: &Outcome) {
        // End-of-trace flush outcomes only exist offline (the router never
        // sees the trace end), so they are excluded from the parity log.
        if !o.done {
            self.outcomes.push(OutcomeKey {
                func: o.func,
                action: o.action,
                t: o.t.to_bits(),
                resolved_t: o.resolved_t.to_bits(),
                reused: o.reused,
                idle_span_s: o.idle_span_s.to_bits(),
                idle_carbon_g: o.idle_carbon_g.to_bits(),
                cold_penalty_s: o.cold_penalty_s.to_bits(),
            });
        }
        self.inner.observe(o);
    }
}

/// Run the same policy (two fresh instances) through the engine and the
/// router on the same trace and compare the full lifecycle bit-for-bit.
fn check_parity<P: KeepAlivePolicy>(
    trace: &Trace,
    ci: &CarbonTrace,
    energy: &EnergyModel,
    engine_policy: P,
    router_policy: P,
) -> Result<(), String> {
    // Offline: simulator engine over the whole trace.
    let mut engine_rec = Rec::new(engine_policy);
    let cfg = SimConfig { track_latencies: true, ..SimConfig::default() };
    let sim = Simulator::new(trace, ci, energy.clone(), cfg).run(&mut engine_rec);
    let name = engine_rec.name().to_string();

    // Online: router driven invocation-by-invocation.
    let mut router = Router::new(
        trace.functions.clone(),
        Rec::new(router_policy),
        ci.clone(),
        energy.clone(),
        RouterConfig::default(),
    );
    let mut latencies = Vec::with_capacity(trace.invocations.len());
    let mut cold = 0u64;
    for (id, inv) in trace.invocations.iter().enumerate() {
        let resp = router.handle(&InvocationRequest {
            id: id as u64,
            t: inv.t,
            func: inv.func,
            exec_s: inv.exec_s,
        });
        latencies.push(resp.latency_s);
        cold += u64::from(resp.cold);
    }
    let (router_rec, rm) = router.into_parts();

    prop_assert!(
        cold == sim.metrics.cold_starts && rm.cold_starts == sim.metrics.cold_starts,
        "{name}: warm/cold split diverges: router {cold} vs engine {}",
        sim.metrics.cold_starts
    );
    prop_assert!(
        latencies.len() == sim.latencies.len()
            && latencies
                .iter()
                .zip(sim.latencies.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits()),
        "{name}: per-invocation latencies diverge"
    );

    // One decision per invocation, identical inputs and outputs.
    prop_assert!(
        router_rec.decides.len() == trace.invocations.len()
            && engine_rec.decides.len() == trace.invocations.len(),
        "{name}: decision counts diverge: router {} / engine {} for {} invocations",
        router_rec.decides.len(),
        engine_rec.decides.len(),
        trace.invocations.len()
    );
    for (i, (a, b)) in
        router_rec.decides.iter().zip(engine_rec.decides.iter()).enumerate()
    {
        prop_assert!(
            a == b,
            "{name}: decision {i} diverges:\n  router {a:?}\n  engine {b:?}"
        );
    }

    // Resolved outcomes (reuse + observed expiry, flush excluded) match
    // bit-for-bit — idle spans, idle carbon, and the exactly-one
    // cold-penalty attribution on tied expiries.
    prop_assert!(
        router_rec.outcomes.len() == engine_rec.outcomes.len(),
        "{name}: outcome counts diverge: router {} vs engine {}",
        router_rec.outcomes.len(),
        engine_rec.outcomes.len()
    );
    for (i, (a, b)) in
        router_rec.outcomes.iter().zip(engine_rec.outcomes.iter()).enumerate()
    {
        prop_assert!(
            a == b,
            "{name}: outcome {i} diverges:\n  router {a:?}\n  engine {b:?}"
        );
    }
    Ok(())
}

#[test]
fn router_lifecycle_matches_engine_bitwise() {
    forall("router lifecycle == engine lifecycle", 4, 291, |rng| {
        let trace = small_trace(rng);
        let ci = random_ci(rng);
        let energy = EnergyModel::default();
        check_parity(&trace, &ci, &energy, FixedTimeout::huawei(), FixedTimeout::huawei())?;
        check_parity(
            &trace,
            &ci,
            &energy,
            FixedTimeout::new(10.0),
            FixedTimeout::new(10.0),
        )?;
        check_parity(&trace, &ci, &energy, LatencyMin, LatencyMin)?;
        check_parity(&trace, &ci, &energy, CarbonMin, CarbonMin)?;
        Ok(())
    });
}
