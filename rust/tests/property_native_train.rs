//! Cross-backend training properties: the pure-Rust `rl::native_train`
//! step must match the AOT PJRT `dqn_train_step` to ≤1e-5 on params and
//! loss over ≥100 shared minibatches (artifacts-gated), and native
//! training must be bit-identical across reruns with the same seed.

use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::energy::model::EnergyModel;
use lace_rl::rl::backend::TrainBackend;
use lace_rl::rl::encoder::STATE_DIM;
use lace_rl::rl::native_train::NativeBackend;
use lace_rl::rl::replay::SampleBatch;
use lace_rl::rl::trainer::{self, TrainerConfig};
use lace_rl::runtime::backend::PjrtBackend;
use lace_rl::runtime::{artifacts, ArtifactSet, PjrtRuntime, TrainStep};
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::util::rng::Rng;

fn open() -> Option<(ArtifactSet, PjrtRuntime)> {
    let dir = artifacts::default_dir();
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping cross-backend agreement test");
        return None;
    }
    let art = ArtifactSet::open(&dir).expect("artifact set");
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    Some((art, rt))
}

/// Random replay-shaped minibatch (both backends consume it verbatim).
fn synthetic_batch(rng: &mut Rng, batch: usize, n_actions: usize) -> SampleBatch {
    let mut sb = SampleBatch::new(batch);
    for x in sb.states.iter_mut() {
        *x = rng.f64() as f32;
    }
    for x in sb.next_states.iter_mut() {
        *x = rng.f64() as f32;
    }
    for a in sb.actions.iter_mut() {
        *a = rng.index(n_actions) as i32;
    }
    for r in sb.rewards.iter_mut() {
        *r = -(rng.f64() as f32) * 2.0;
    }
    for d in sb.dones.iter_mut() {
        *d = if rng.chance(0.15) { 1.0 } else { 0.0 };
    }
    sb
}

#[test]
fn native_matches_pjrt_params_and_loss_over_100_steps() {
    let Some((art, rt)) = open() else { return };
    let dims = art.manifest.dims();
    let b = art.manifest.train_batch;
    assert_eq!(dims.0, STATE_DIM, "manifest state_dim must match encoder");
    let init = art.init_params().unwrap();

    let step = TrainStep::new(
        rt.load_hlo_text(art.train_step_path().to_str().unwrap()).unwrap(),
        b,
        dims,
    );
    let mut pjrt = PjrtBackend::new(step, init.clone());
    let mut native = NativeBackend::new(init, b);

    let mut rng = Rng::new(7);
    let mut worst_params = 0.0f32;
    let mut worst_loss = 0.0f32;
    for t in 1..=120u64 {
        let sb = synthetic_batch(&mut rng, b, dims.3);
        let loss_pjrt = pjrt.step(t, &sb).unwrap();
        let loss_native = native.step(t, &sb).unwrap();
        worst_loss = worst_loss.max((loss_pjrt - loss_native).abs());
        worst_params = worst_params.max(pjrt.params().max_abs_diff(native.params()));
        // Sync both on the same cadence, mid-run, so target divergence
        // would compound and get caught.
        if t % 25 == 0 {
            pjrt.sync_target();
            native.sync_target();
        }
    }
    assert!(
        worst_params <= 1e-5,
        "params diverged between backends: max |Δ| = {worst_params:e}"
    );
    assert!(worst_loss <= 1e-5, "loss diverged between backends: max |Δ| = {worst_loss:e}");
}

#[test]
fn native_training_bit_identical_across_reruns() {
    // No artifacts required: this is the determinism half of the
    // tentpole's acceptance criteria, over >100 steps with target syncs.
    let run = || {
        let init = lace_rl::rl::qnet::QNetParams::he_uniform(trainer::default_dims(), 41);
        let mut backend = NativeBackend::new(init, 64);
        let mut rng = Rng::new(13);
        let mut losses = Vec::new();
        for t in 1..=110u64 {
            let sb = synthetic_batch(&mut rng, 64, trainer::default_dims().3);
            losses.push(backend.step(t, &sb).unwrap());
            if t % 30 == 0 {
                backend.sync_target();
            }
        }
        (backend.params().clone(), losses)
    };
    let (pa, la) = run();
    let (pb, lb) = run();
    assert_eq!(pa.max_abs_diff(&pb), 0.0, "params must be bit-identical across reruns");
    assert!(
        la.iter().zip(lb.iter()).all(|(x, y)| x.to_bits() == y.to_bits()),
        "per-step losses must be bit-identical across reruns"
    );
}

#[test]
fn native_trainer_smoke_end_to_end() {
    // The full trainer loop (rollout → replay → gradient steps → target
    // syncs) on the native backend, twice, without any PJRT artifacts:
    // must run, must learn on *something* (nonzero steps), and must be
    // exactly reproducible.
    let trace = TraceGenerator::new(SynthConfig {
        n_functions: 20,
        duration_s: 1_800.0,
        target_invocations: 4_000,
        seed: 55,
        ..SynthConfig::default()
    })
    .generate();
    let ci = synth_region(Region::SolarHeavy, 1, 55);
    let energy = EnergyModel::default();
    let cfg = TrainerConfig {
        lambda_carbon: Some(0.5),
        seed: 55,
        ..TrainerConfig::smoke()
    };

    let a = trainer::train_native(&trace, &ci, &energy, &cfg).unwrap();
    let b = trainer::train_native(&trace, &ci, &energy, &cfg).unwrap();

    assert_eq!(a.backend, "native");
    assert!(a.total_steps > 0, "smoke schedule must run gradient steps");
    assert_eq!(a.total_steps, b.total_steps);
    assert_eq!(
        a.params.max_abs_diff(&b.params),
        0.0,
        "native end-to-end training must be reproducible"
    );
    assert!(a.episodes.iter().all(|e| e.grad_steps_per_s >= 0.0));
}

#[test]
fn trainer_config_rejects_zero_target_sync_before_training() {
    // The modulo-by-zero guard must fire at validation time, not deep in
    // the gradient loop.
    let trace = TraceGenerator::new(SynthConfig {
        n_functions: 5,
        duration_s: 600.0,
        target_invocations: 500,
        seed: 3,
        ..SynthConfig::default()
    })
    .generate();
    let ci = synth_region(Region::SolarHeavy, 1, 3);
    let energy = EnergyModel::default();
    let cfg = TrainerConfig { target_sync_steps: 0, ..TrainerConfig::smoke() };
    let err = trainer::train_native(&trace, &ci, &energy, &cfg).unwrap_err();
    assert!(err.to_string().contains("target_sync_steps"), "got: {err:#}");
}
