//! Properties of the `obs` telemetry layer:
//!
//! 1. Collection is observation-only — turning it on changes no simulation
//!    output bit (metrics and tracked latencies identical).
//! 2. Merged telemetry is shard-count-invariant — a sharded run's `SimObs`
//!    (and its JSONL rendering) equals the sequential run's, f64 bits
//!    included, for every shard count.
//! 3. The telemetry totals track the run's `SimMetrics` bitwise: the
//!    accumulators are recorded adjacent to each metrics update and folded
//!    under the same id-order contract, so the sums cannot drift.

use lace_rl::carbon::intensity::CarbonTrace;
use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::energy::model::EnergyModel;
use lace_rl::policy::dpso::{Dpso, DpsoConfig};
use lace_rl::policy::{BoxedPolicy, CarbonMin, FixedTimeout, LatencyMin};
use lace_rl::prop_assert;
use lace_rl::simulator::engine::{SimConfig, Simulator};
use lace_rl::simulator::sharded::ShardedSimulator;
use lace_rl::trace::model::Trace;
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::util::quickcheck::forall;
use lace_rl::util::rng::Rng;

fn small_trace(rng: &mut Rng) -> Trace {
    let cfg = SynthConfig {
        n_functions: 8 + rng.index(20),
        duration_s: 600.0 + rng.f64() * 1200.0,
        target_invocations: 2_000 + rng.index(3_000),
        seed: rng.next_u64(),
        ..SynthConfig::default()
    };
    TraceGenerator::new(cfg).generate()
}

fn random_ci(rng: &mut Rng) -> CarbonTrace {
    match rng.index(2) {
        0 => CarbonTrace::constant(100.0 + rng.f64() * 600.0),
        _ => synth_region(Region::SolarHeavy, 1, rng.next_u64()),
    }
}

fn policy_grid() -> Vec<(&'static str, Box<dyn Fn() -> BoxedPolicy>)> {
    vec![
        ("huawei-60s", Box::new(|| Box::new(FixedTimeout::huawei()) as BoxedPolicy)),
        ("latency-min", Box::new(|| Box::new(LatencyMin) as BoxedPolicy)),
        ("carbon-min", Box::new(|| Box::new(CarbonMin) as BoxedPolicy)),
        (
            "dpso-ecolife",
            Box::new(|| Box::new(Dpso::new(DpsoConfig::default())) as BoxedPolicy),
        ),
    ]
}

#[test]
fn collection_is_observation_only() {
    forall("obs collection leaves results bit-identical", 4, 271, |rng| {
        let trace = small_trace(rng);
        let ci = random_ci(rng);
        let energy = EnergyModel::default();
        let lambda = *rng.choice(&[0.2, 0.5, 0.8]);

        for (name, factory) in policy_grid() {
            let base = SimConfig {
                lambda_carbon: lambda,
                track_latencies: true,
                ..SimConfig::default()
            };
            let with_obs = SimConfig { collect_obs: true, ..base.clone() };

            let mut p = factory();
            let off = Simulator::new(&trace, &ci, energy.clone(), base).run(p.as_mut());
            let mut p = factory();
            let on =
                Simulator::new(&trace, &ci, energy.clone(), with_obs.clone()).run(p.as_mut());

            prop_assert!(off.obs.is_none(), "{name}: obs present while disabled");
            prop_assert!(on.obs.is_some(), "{name}: obs missing while enabled");
            prop_assert!(
                off.metrics.cold_starts == on.metrics.cold_starts
                    && off.metrics.warm_starts == on.metrics.warm_starts
                    && off.metrics.invocations == on.metrics.invocations,
                "{name}: counts changed by collection"
            );
            for (field, x, y) in [
                ("keepalive_carbon_g", off.metrics.keepalive_carbon_g, on.metrics.keepalive_carbon_g),
                ("exec_carbon_g", off.metrics.exec_carbon_g, on.metrics.exec_carbon_g),
                ("cold_carbon_g", off.metrics.cold_carbon_g, on.metrics.cold_carbon_g),
                ("cold_latency_s", off.metrics.cold_latency_s, on.metrics.cold_latency_s),
                ("latency_sum", off.metrics.latency.sum, on.metrics.latency.sum),
            ] {
                prop_assert!(
                    x.to_bits() == y.to_bits(),
                    "{name}: {field} changed by collection: {x:e} vs {y:e}"
                );
            }
            prop_assert!(
                off.latencies.len() == on.latencies.len()
                    && off
                        .latencies
                        .iter()
                        .zip(on.latencies.iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                "{name}: tracked latencies changed by collection"
            );

            // Sharded path: same property.
            let mut p = factory();
            let sh_on = ShardedSimulator::new(&trace, &ci, energy.clone(), with_obs)
                .with_shards(4)
                .run(p.as_mut());
            prop_assert!(
                sh_on.metrics.keepalive_carbon_g.to_bits()
                    == off.metrics.keepalive_carbon_g.to_bits(),
                "{name}: sharded+obs keepalive carbon drifted"
            );
        }
        Ok(())
    });
}

#[test]
fn merged_telemetry_is_shard_count_invariant() {
    forall("sharded obs == sequential obs", 4, 272, |rng| {
        let trace = small_trace(rng);
        let ci = random_ci(rng);
        let energy = EnergyModel::default();
        let nf = trace.functions.len();
        let cfg = SimConfig { collect_obs: true, ..SimConfig::default() };

        for (name, factory) in policy_grid() {
            let mut p = factory();
            let seq = Simulator::new(&trace, &ci, energy.clone(), cfg.clone()).run(p.as_mut());
            let seq_obs = seq.obs.expect("collection on");
            let seq_jsonl: Vec<String> =
                seq_obs.jsonl_lines(name).iter().map(|l| l.to_string()).collect();

            for k in [2usize, 5, nf] {
                let mut p = factory();
                let sh = ShardedSimulator::new(&trace, &ci, energy.clone(), cfg.clone())
                    .with_shards(k)
                    .run(p.as_mut());
                let sh_obs = sh.obs.expect("collection on");
                prop_assert!(
                    sh_obs == seq_obs,
                    "{name} k={k}: merged telemetry differs from sequential"
                );
                let sh_jsonl: Vec<String> =
                    sh_obs.jsonl_lines(name).iter().map(|l| l.to_string()).collect();
                prop_assert!(
                    sh_jsonl == seq_jsonl,
                    "{name} k={k}: JSONL rendering differs from sequential"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn totals_track_sim_metrics_bitwise() {
    forall("obs totals == sim metrics", 5, 273, |rng| {
        let trace = small_trace(rng);
        let ci = random_ci(rng);
        let energy = EnergyModel::default();
        let cfg = SimConfig { collect_obs: true, ..SimConfig::default() };

        for (name, factory) in policy_grid() {
            let mut p = factory();
            let r = ShardedSimulator::new(&trace, &ci, energy.clone(), cfg.clone())
                .with_shards(3)
                .run(p.as_mut());
            let t = &r.obs.as_ref().expect("collection on").totals;
            let m = &r.metrics;
            prop_assert!(
                t.cold_starts == m.cold_starts && t.warm_starts == m.warm_starts,
                "{name}: start counts diverge: obs {}/{} vs metrics {}/{}",
                t.cold_starts,
                t.warm_starts,
                m.cold_starts,
                m.warm_starts
            );
            prop_assert!(
                t.idle_carbon_g.to_bits() == m.keepalive_carbon_g.to_bits(),
                "{name}: idle carbon diverges: obs {:e} vs metrics {:e}",
                t.idle_carbon_g,
                m.keepalive_carbon_g
            );
            prop_assert!(
                t.cold_latency_s.to_bits() == m.cold_latency_s.to_bits(),
                "{name}: cold latency diverges"
            );
            // Exactly one keep-alive decision per invocation.
            prop_assert!(
                t.keep_hist.count == m.invocations,
                "{name}: {} decisions for {} invocations",
                t.keep_hist.count,
                m.invocations
            );
            prop_assert!(
                t.cold_hist.count == m.cold_starts,
                "{name}: cold histogram count diverges"
            );
            // The wasted (expiry) subset never exceeds total idle carbon.
            prop_assert!(
                t.expiry_carbon_g <= t.idle_carbon_g + 1e-12,
                "{name}: expiry carbon exceeds idle carbon"
            );
        }
        Ok(())
    });
}
