//! Property: the parallel sweep harness is a pure speedup — for every cell,
//! [`SweepRunner`] must return metrics **bit-identical** to a sequential
//! `Simulator::run` with an identically-constructed fresh policy. Thread
//! scheduling may reorder execution, never results.

use lace_rl::carbon::intensity::CarbonTrace;
use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::energy::model::EnergyModel;
use lace_rl::policy::dpso::{Dpso, DpsoConfig};
use lace_rl::policy::{CarbonMin, FixedTimeout, LatencyMin};
use lace_rl::prop_assert;
use lace_rl::simulator::engine::{SimConfig, Simulator};
use lace_rl::simulator::parallel::{BoxedPolicy, SweepCell, SweepRunner};
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::util::quickcheck::forall;
use lace_rl::util::rng::Rng;

/// The policy grid each sweep runs: every factory builds a *fresh* policy,
/// so sequential reference and parallel cell start from identical state.
fn policy_grid() -> Vec<(&'static str, Box<dyn Fn() -> BoxedPolicy + Send + Sync>)> {
    vec![
        ("huawei-60s", Box::new(|| Box::new(FixedTimeout::huawei()) as BoxedPolicy)),
        ("fixed-10s", Box::new(|| Box::new(FixedTimeout::new(10.0)) as BoxedPolicy)),
        ("latency-min", Box::new(|| Box::new(LatencyMin) as BoxedPolicy)),
        ("carbon-min", Box::new(|| Box::new(CarbonMin) as BoxedPolicy)),
        ("dpso-ecolife", Box::new(|| Box::new(Dpso::new(DpsoConfig::default())) as BoxedPolicy)),
    ]
}

fn small_trace(rng: &mut Rng) -> lace_rl::trace::model::Trace {
    let cfg = SynthConfig {
        n_functions: 10 + rng.index(30),
        duration_s: 600.0 + rng.f64() * 1200.0,
        target_invocations: 2_000 + rng.index(6_000),
        seed: rng.next_u64(),
        ..SynthConfig::default()
    };
    TraceGenerator::new(cfg).generate()
}

#[test]
fn sweep_results_bit_identical_to_sequential() {
    // ≥3 seeds: forall runs 4 independent randomized cases.
    forall("parallel sweep == sequential run", 4, 113, |rng| {
        let trace = small_trace(rng);
        let ci = match rng.index(2) {
            0 => CarbonTrace::constant(100.0 + rng.f64() * 600.0),
            _ => synth_region(Region::SolarHeavy, 1, rng.next_u64()),
        };
        let energy = EnergyModel::default();
        let lambda = *rng.choice(&[0.2, 0.5, 0.8]);
        let window = *rng.choice(&[32usize, 64]);
        let cfg = SimConfig {
            lambda_carbon: lambda,
            reuse_window: window,
            ..SimConfig::default()
        };

        // Sequential reference: one fresh policy per cell, plain Simulator.
        let grid = policy_grid();
        let mut reference = Vec::new();
        for (_, factory) in &grid {
            let mut policy = factory();
            let sim = Simulator::new(&trace, &ci, energy.clone(), cfg.clone());
            reference.push(sim.run(policy.as_mut()).metrics);
        }

        // Parallel sweep over the same grid.
        let cells = policy_grid()
            .into_iter()
            .map(|(label, factory)| SweepCell::new(label, cfg.clone(), factory))
            .collect();
        let outcomes =
            SweepRunner::new(&trace, &ci, energy.clone()).with_threads(8).run(cells);

        prop_assert!(outcomes.len() == reference.len(), "cell count mismatch");
        for ((name, _), (seq, out)) in
            grid.iter().zip(reference.iter().zip(outcomes.iter()))
        {
            let par = &out.result.metrics;
            prop_assert!(out.label == *name, "order broken: {} vs {name}", out.label);
            prop_assert!(
                par.cold_starts == seq.cold_starts && par.warm_starts == seq.warm_starts,
                "{name}: cold/warm {}/{} vs {}/{}",
                par.cold_starts,
                par.warm_starts,
                seq.cold_starts,
                seq.warm_starts
            );
            prop_assert!(par.invocations == seq.invocations, "{name}: invocations");
            // Carbon, latency and idle accounting must match to the bit —
            // parallelism may not perturb a single FP operation.
            for (field, a, b) in [
                ("keepalive_carbon_g", par.keepalive_carbon_g, seq.keepalive_carbon_g),
                ("exec_carbon_g", par.exec_carbon_g, seq.exec_carbon_g),
                ("cold_carbon_g", par.cold_carbon_g, seq.cold_carbon_g),
                ("cold_latency_s", par.cold_latency_s, seq.cold_latency_s),
                ("idle_pod_seconds", par.idle_pod_seconds, seq.idle_pod_seconds),
                ("wasted_idle_seconds", par.wasted_idle_seconds, seq.wasted_idle_seconds),
                ("latency_sum", par.latency.sum, seq.latency.sum),
            ] {
                prop_assert!(
                    a.to_bits() == b.to_bits(),
                    "{name}: {field} differs: {a:e} vs {b:e}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn sweep_deterministic_across_repeat_runs() {
    // Same cells, run twice through the pool: identical outcomes (the
    // atomic work-stealing cursor must not leak scheduling into results).
    let trace = TraceGenerator::new(SynthConfig::small(9)).generate();
    let ci = synth_region(Region::FossilHeavy, 1, 9);
    let runner = SweepRunner::new(&trace, &ci, EnergyModel::default());
    let cells = || {
        policy_grid()
            .into_iter()
            .map(|(label, factory)| SweepCell::new(label, SimConfig::default(), factory))
            .collect::<Vec<_>>()
    };
    let a = runner.run(cells());
    let b = runner.run(cells());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.label, y.label);
        assert_eq!(x.result.metrics.cold_starts, y.result.metrics.cold_starts);
        assert_eq!(
            x.result.metrics.total_carbon_g().to_bits(),
            y.result.metrics.total_carbon_g().to_bits()
        );
    }
}
