//! Property tests on the RL substrate: encoder bounds, replay-buffer
//! invariants, weight-format round trips, and native-MLP numerics.

use lace_rl::policy::native_mlp::NativeMlp;
use lace_rl::policy::DecisionContext;
use lace_rl::prop_assert;
use lace_rl::rl::encoder::{encode, STATE_DIM};
use lace_rl::rl::qnet::QNetParams;
use lace_rl::rl::replay::{ReplayBuffer, SampleBatch, Transition};
use lace_rl::rl::weights;
use lace_rl::trace::model::{FunctionProfile, Runtime, TriggerType};
use lace_rl::util::quickcheck::forall;
use lace_rl::util::rng::Rng;

fn random_profile(rng: &mut Rng) -> FunctionProfile {
    FunctionProfile {
        id: rng.below(1000) as u32,
        runtime: *rng.choice(&Runtime::ALL),
        trigger: TriggerType::Http,
        mem_mb: rng.f64() * 5000.0,
        cpu_cores: 1.0 + rng.f64() * 8.0,
        cold_start_s: rng.f64() * 30.0,
        mean_exec_s: rng.f64(),
    }
}

#[test]
fn encoder_output_always_bounded() {
    forall("encoder bounds", 200, 301, |rng| {
        let prof = random_profile(rng);
        let mut probs = [0.0; 5];
        for p in probs.iter_mut() {
            *p = rng.f64();
        }
        probs.sort_by(|a, b| a.partial_cmp(b).unwrap()); // monotone like real ones
        let ctx = DecisionContext {
            t: rng.f64() * 1e6,
            func: &prof,
            ci: rng.f64() * 2000.0,
            reuse_probs: probs,
            lambda_carbon: rng.f64(),
            idle_power_w: rng.f64() * 100.0,
            next_arrival_gap: None,
        };
        let s = encode(&ctx);
        for (i, v) in s.iter().enumerate() {
            prop_assert!((0.0..=1.0).contains(v), "feature {i} out of bounds: {v}");
            prop_assert!(v.is_finite(), "feature {i} not finite");
        }
        Ok(())
    });
}

#[test]
fn encoder_is_deterministic_and_injective_in_lambda() {
    forall("encoder lambda", 50, 302, |rng| {
        let prof = random_profile(rng);
        let base = DecisionContext {
            t: 0.0,
            func: &prof,
            ci: 400.0,
            reuse_probs: [0.2, 0.4, 0.6, 0.8, 0.9],
            lambda_carbon: rng.f64(),
            idle_power_w: 1.0,
            next_arrival_gap: None,
        };
        let a = encode(&base);
        let b = encode(&base);
        prop_assert!(a == b, "encoding not deterministic");
        let mut other = base.clone();
        other.lambda_carbon = (base.lambda_carbon + 0.31) % 1.0;
        let c = encode(&other);
        prop_assert!(a[9] != c[9], "lambda feature must move with lambda");
        Ok(())
    });
}

#[test]
fn replay_never_exceeds_capacity_and_samples_valid() {
    forall("replay invariants", 40, 303, |rng| {
        let cap = 1 + rng.index(200);
        let mut rb = ReplayBuffer::new(cap);
        let n = rng.index(500);
        for i in 0..n {
            rb.push(Transition {
                state: [i as f32; STATE_DIM],
                action: (i % 5) as u8,
                reward: -(i as f32),
                next_state: [0.0; STATE_DIM],
                done: i % 7 == 0,
            });
        }
        prop_assert!(rb.len() <= cap, "len {} > capacity {cap}", rb.len());
        prop_assert!(rb.len() == n.min(cap), "len wrong");
        if rb.len() > 0 {
            let batch = 1 + rng.index(64);
            let mut s = vec![0.0; batch * STATE_DIM];
            let mut a = vec![0i32; batch];
            let mut r = vec![0.0f32; batch];
            let mut ns = vec![0.0; batch * STATE_DIM];
            let mut d = vec![0.0f32; batch];
            rb.sample_into(rng, batch, &mut s, &mut a, &mut r, &mut ns, &mut d);
            for b in 0..batch {
                prop_assert!((0..5).contains(&a[b]), "action out of range");
                prop_assert!(d[b] == 0.0 || d[b] == 1.0, "done not boolean");
                // Sampled transitions must be among the retained (newest) ones.
                let v = s[b * STATE_DIM] as usize;
                prop_assert!(v < n, "sampled state from the future");
                prop_assert!(
                    n <= cap || v >= n - cap,
                    "sampled an evicted transition ({v} with n={n} cap={cap})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn sample_into_is_deterministic_in_the_seed() {
    // Both train backends consume the same sampled minibatches; replay
    // sampling being a pure function of the RNG seed is what makes
    // cross-backend agreement and bit-identical native reruns possible.
    forall("sample_into determinism", 30, 307, |rng| {
        let cap = 1 + rng.index(200);
        let mut rb = ReplayBuffer::new(cap);
        let n = 1 + rng.index(300);
        for i in 0..n {
            rb.push(Transition {
                state: [i as f32; STATE_DIM],
                action: (i % 5) as u8,
                reward: -(i as f32),
                next_state: [i as f32 + 0.5; STATE_DIM],
                done: i % 3 == 0,
            });
        }
        let batch = 1 + rng.index(64);
        let seed = rng.next_u64();
        let draw = |seed: u64| {
            let mut r = Rng::new(seed);
            let mut sb = SampleBatch::new(batch);
            rb.sample_batch(&mut r, &mut sb);
            sb
        };
        let a = draw(seed);
        let b = draw(seed);
        prop_assert!(
            a.states == b.states
                && a.actions == b.actions
                && a.rewards == b.rewards
                && a.next_states == b.next_states
                && a.dones == b.dones,
            "same seed must fill identical flat buffers (cap={cap} n={n} batch={batch})"
        );
        Ok(())
    });
}

#[test]
fn weights_roundtrip_random_params() {
    forall("weights roundtrip", 25, 304, |rng| {
        let dims = (
            1 + rng.index(16),
            1 + rng.index(96),
            1 + rng.index(96),
            1 + rng.index(8),
        );
        let mut p = QNetParams::zeros(dims);
        for t in p.tensors_mut() {
            for v in t.iter_mut() {
                *v = rng.normal(0.0, 1.0) as f32;
            }
        }
        let path = std::env::temp_dir().join(format!(
            "lace_rl_prop_weights_{}.bin",
            rng.next_u64()
        ));
        let path_str = path.to_str().unwrap();
        weights::save_params(path_str, &p).map_err(|e| e.to_string())?;
        let q = weights::load_params(path_str).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        prop_assert!(p == q, "roundtrip mismatch for dims {dims:?}");
        Ok(())
    });
}

#[test]
fn native_mlp_matches_f64_reference_on_random_nets() {
    forall("native mlp numerics", 30, 305, |rng| {
        let dims = (
            1 + rng.index(16),
            1 + rng.index(64),
            1 + rng.index(64),
            1 + rng.index(8),
        );
        let mut p = QNetParams::zeros(dims);
        for t in p.tensors_mut() {
            for v in t.iter_mut() {
                *v = rng.normal(0.0, 0.5) as f32;
            }
        }
        let x: Vec<f32> = (0..dims.0).map(|_| rng.normal(0.0, 1.0) as f32).collect();

        // f64 reference
        let dense = |x: &[f64], w: &[f32], b: &[f32], n_out: usize, relu: bool| {
            let mut y = vec![0.0f64; n_out];
            for j in 0..n_out {
                let mut acc = b[j] as f64;
                for (i, &xi) in x.iter().enumerate() {
                    acc += xi * w[i * n_out + j] as f64;
                }
                y[j] = if relu { acc.max(0.0) } else { acc };
            }
            y
        };
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let h1 = dense(&x64, &p.w1, &p.b1, dims.1, true);
        let h2 = dense(&h1, &p.w2, &p.b2, dims.2, true);
        let want = dense(&h2, &p.w3, &p.b3, dims.3, false);

        let mut mlp = NativeMlp::new(p);
        let got = mlp.forward(&x);
        for (g, w) in got.iter().zip(want.iter()) {
            prop_assert!(
                (*g as f64 - w).abs() < 1e-3 + w.abs() * 1e-4,
                "mlp {g} vs ref {w} at dims {dims:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn argmax_consistent_with_forward() {
    forall("argmax consistency", 40, 306, |rng| {
        let mut p = QNetParams::zeros((STATE_DIM, 16, 16, 5));
        for t in p.tensors_mut() {
            for v in t.iter_mut() {
                *v = rng.normal(0.0, 0.7) as f32;
            }
        }
        let x: Vec<f32> = (0..STATE_DIM).map(|_| rng.f64() as f32).collect();
        let mut mlp = NativeMlp::new(p);
        let q = mlp.forward(&x).to_vec();
        let a = mlp.argmax(&x);
        let max = q.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        prop_assert!(q[a] == max, "argmax {a} not maximal: {q:?}");
        Ok(())
    });
}
