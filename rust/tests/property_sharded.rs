//! Property: function-sharded replay is a pure speedup — for every policy
//! that forks, [`ShardedSimulator`] must return metrics **and** tracked
//! latencies bit-identical to a sequential [`Simulator::run`] with an
//! identically-constructed fresh policy, for every shard count. The thread
//! count may reorder execution, never results.

use lace_rl::carbon::intensity::CarbonTrace;
use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::energy::model::EnergyModel;
use lace_rl::policy::dpso::{Dpso, DpsoConfig};
use lace_rl::policy::lace_rl::LaceRlPolicy;
use lace_rl::policy::native_mlp::NativeMlp;
use lace_rl::policy::{BoxedPolicy, CarbonMin, FixedTimeout, LatencyMin, Oracle};
use lace_rl::prop_assert;
use lace_rl::rl::agent::EpsilonGreedyAgent;
use lace_rl::rl::encoder::STATE_DIM;
use lace_rl::rl::qnet::QNetParams;
use lace_rl::simulator::engine::{SimConfig, SimResult, Simulator};
use lace_rl::simulator::sharded::ShardedSimulator;
use lace_rl::trace::model::Trace;
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::util::quickcheck::forall;
use lace_rl::util::rng::Rng;

fn small_trace(rng: &mut Rng) -> Trace {
    let cfg = SynthConfig {
        n_functions: 8 + rng.index(30),
        duration_s: 600.0 + rng.f64() * 1200.0,
        target_invocations: 2_000 + rng.index(5_000),
        seed: rng.next_u64(),
        ..SynthConfig::default()
    };
    TraceGenerator::new(cfg).generate()
}

fn random_ci(rng: &mut Rng) -> CarbonTrace {
    match rng.index(2) {
        0 => CarbonTrace::constant(100.0 + rng.f64() * 600.0),
        _ => synth_region(Region::SolarHeavy, 1, rng.next_u64()),
    }
}

/// Small random Q-network so the LACE-RL cell exercises non-trivial argmax
/// paths (zero weights would tie every action).
fn random_params(rng: &mut Rng) -> QNetParams {
    let mut p = QNetParams::zeros((STATE_DIM, 8, 8, 5));
    for t in p.tensors_mut() {
        for w in t.iter_mut() {
            *w = (rng.f64() * 2.0 - 1.0) as f32;
        }
    }
    p
}

/// Every shipped forkable policy; the bool marks Oracle cells needing the
/// clairvoyant next-arrival gap.
#[allow(clippy::type_complexity)]
fn policy_grid(rng: &mut Rng) -> Vec<(&'static str, bool, Box<dyn Fn() -> BoxedPolicy>)> {
    let params = random_params(rng);
    vec![
        ("huawei-60s", false, Box::new(|| Box::new(FixedTimeout::huawei()) as BoxedPolicy)),
        ("fixed-10s", false, Box::new(|| Box::new(FixedTimeout::new(10.0)) as BoxedPolicy)),
        ("latency-min", false, Box::new(|| Box::new(LatencyMin) as BoxedPolicy)),
        ("carbon-min", false, Box::new(|| Box::new(CarbonMin) as BoxedPolicy)),
        (
            "dpso-ecolife",
            false,
            Box::new(|| Box::new(Dpso::new(DpsoConfig::default())) as BoxedPolicy),
        ),
        ("oracle", true, Box::new(|| Box::new(Oracle) as BoxedPolicy)),
        (
            "lace-rl",
            false,
            Box::new(move || {
                Box::new(LaceRlPolicy::new(NativeMlp::new(params.clone()))) as BoxedPolicy
            }),
        ),
    ]
}

/// Bit-level equality of two simulation results.
fn assert_same(name: &str, k: usize, seq: &SimResult, sh: &SimResult) -> Result<(), String> {
    let (a, b) = (&seq.metrics, &sh.metrics);
    prop_assert!(
        a.invocations == b.invocations
            && a.cold_starts == b.cold_starts
            && a.warm_starts == b.warm_starts,
        "{name} k={k}: counts {}/{}/{} vs {}/{}/{}",
        a.invocations,
        a.cold_starts,
        a.warm_starts,
        b.invocations,
        b.cold_starts,
        b.warm_starts
    );
    for (field, x, y) in [
        ("keepalive_carbon_g", a.keepalive_carbon_g, b.keepalive_carbon_g),
        ("exec_carbon_g", a.exec_carbon_g, b.exec_carbon_g),
        ("cold_carbon_g", a.cold_carbon_g, b.cold_carbon_g),
        ("cold_latency_s", a.cold_latency_s, b.cold_latency_s),
        ("idle_pod_seconds", a.idle_pod_seconds, b.idle_pod_seconds),
        ("wasted_idle_seconds", a.wasted_idle_seconds, b.wasted_idle_seconds),
        ("latency_sum", a.latency.sum, b.latency.sum),
    ] {
        prop_assert!(
            x.to_bits() == y.to_bits(),
            "{name} k={k}: {field} differs: {x:e} vs {y:e}"
        );
    }
    prop_assert!(
        seq.latencies.len() == sh.latencies.len(),
        "{name} k={k}: latency count {} vs {}",
        seq.latencies.len(),
        sh.latencies.len()
    );
    for (i, (x, y)) in seq.latencies.iter().zip(sh.latencies.iter()).enumerate() {
        prop_assert!(
            x.to_bits() == y.to_bits(),
            "{name} k={k}: latency[{i}] differs: {x:e} vs {y:e}"
        );
    }
    Ok(())
}

#[test]
fn sharded_replay_bit_identical_to_sequential() {
    forall("sharded run == sequential run", 4, 211, |rng| {
        let trace = small_trace(rng);
        let ci = random_ci(rng);
        let energy = EnergyModel::default();
        let lambda = *rng.choice(&[0.2, 0.5, 0.8]);
        let nf = trace.functions.len();

        for (name, oracle_gap, factory) in policy_grid(rng) {
            let cfg = SimConfig {
                lambda_carbon: lambda,
                provide_oracle_gap: oracle_gap,
                track_latencies: true,
                ..SimConfig::default()
            };
            let mut policy = factory();
            let seq = Simulator::new(&trace, &ci, energy.clone(), cfg.clone())
                .run(policy.as_mut());
            for k in [1usize, 2, 7, nf] {
                let mut policy = factory();
                let sh = ShardedSimulator::new(&trace, &ci, energy.clone(), cfg.clone())
                    .with_shards(k)
                    .run(policy.as_mut());
                assert_same(name, k, &seq, &sh)?;
            }
        }
        Ok(())
    });
}

#[test]
fn training_agent_rollout_is_shard_invariant() {
    // The ε-greedy trainer agent is the hard case: stochastic exploration
    // plus harvested transitions. Per-function RNG streams and canonical
    // drain order must make both ends of the rollout — metrics *and* the
    // replay stream — independent of the shard count.
    forall("agent rollout shard-invariant", 3, 212, |rng| {
        let trace = small_trace(rng);
        let ci = random_ci(rng);
        let energy = EnergyModel::default();
        let params = random_params(rng);
        let seed = rng.next_u64();
        let cfg = SimConfig { track_latencies: true, ..SimConfig::default() };

        let mut seq_agent = EpsilonGreedyAgent::new(NativeMlp::new(params.clone()), 0.3, seed);
        let seq = Simulator::new(&trace, &ci, energy.clone(), cfg.clone()).run(&mut seq_agent);
        let seq_transitions = seq_agent.take_transitions();

        for k in [2usize, 7] {
            let mut agent = EpsilonGreedyAgent::new(NativeMlp::new(params.clone()), 0.3, seed);
            let sh = ShardedSimulator::new(&trace, &ci, energy.clone(), cfg.clone())
                .with_shards(k)
                .run(&mut agent);
            assert_same("epsilon-greedy", k, &seq, &sh)?;
            prop_assert!(
                agent.decisions == seq_agent.decisions,
                "k={k}: decisions {} vs {}",
                agent.decisions,
                seq_agent.decisions
            );
            // Summed per shard then merged, so only approximately equal.
            prop_assert!(
                (agent.episode_reward - seq_agent.episode_reward).abs()
                    <= 1e-9 * (1.0 + seq_agent.episode_reward.abs()),
                "k={k}: episode reward {} vs {}",
                agent.episode_reward,
                seq_agent.episode_reward
            );
            let transitions = agent.take_transitions();
            prop_assert!(
                transitions == seq_transitions,
                "k={k}: replay stream differs ({} vs {} transitions)",
                transitions.len(),
                seq_transitions.len()
            );
        }
        Ok(())
    });
}

#[test]
fn degenerate_traces_and_shard_counts() {
    // Empty trace: nothing to do, any shard count.
    let empty = Trace::default();
    let ci = CarbonTrace::constant(300.0);
    for k in [1usize, 4] {
        let r = ShardedSimulator::new(&empty, &ci, EnergyModel::default(), SimConfig::default())
            .with_shards(k)
            .run(&mut FixedTimeout::huawei());
        assert_eq!(r.metrics.invocations, 0);
    }

    // Single-function trace: clamps to one shard, still sequential-equal.
    let trace = TraceGenerator::new(SynthConfig {
        n_functions: 1,
        duration_s: 600.0,
        target_invocations: 500,
        seed: 13,
        ..SynthConfig::default()
    })
    .generate();
    let cfg = SimConfig { track_latencies: true, ..SimConfig::default() };
    let seq = Simulator::new(&trace, &ci, EnergyModel::default(), cfg.clone())
        .run(&mut FixedTimeout::huawei());
    // More shards than functions: clamps to nf, still sequential-equal.
    for k in [1usize, 3, 64] {
        let sh = ShardedSimulator::new(&trace, &ci, EnergyModel::default(), cfg.clone())
            .with_shards(k)
            .run(&mut FixedTimeout::huawei());
        assert_eq!(seq.metrics.cold_starts, sh.metrics.cold_starts);
        assert_eq!(
            seq.metrics.total_carbon_g().to_bits(),
            sh.metrics.total_carbon_g().to_bits()
        );
        assert_eq!(seq.latencies.len(), sh.latencies.len());
    }
}
