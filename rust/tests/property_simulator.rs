//! Property tests on simulator invariants over randomized workloads,
//! using the in-repo quickcheck substrate.

use lace_rl::carbon::intensity::CarbonTrace;
use lace_rl::carbon::synth::{synth_region, Region};
use lace_rl::energy::model::EnergyModel;
use lace_rl::policy::{blended_cost, FixedTimeout, Oracle};
use lace_rl::prop_assert;
use lace_rl::simulator::engine::{SimConfig, Simulator};
use lace_rl::trace::synth::{SynthConfig, TraceGenerator};
use lace_rl::util::quickcheck::forall;
use lace_rl::util::rng::Rng;

fn random_trace(rng: &mut Rng) -> lace_rl::trace::model::Trace {
    let cfg = SynthConfig {
        n_functions: 5 + rng.index(40),
        duration_s: 300.0 + rng.f64() * 3000.0,
        target_invocations: 500 + rng.index(5_000),
        gap_median_s: 2.0 + rng.f64() * 20.0,
        gap_sigma: 0.8 + rng.f64(),
        bursty_frac: rng.f64() * 0.5,
        periodic_frac: rng.f64() * 0.3,
        diurnal: rng.chance(0.5),
        sparse_frac: rng.f64() * 0.4,
        sparse_gap_median_s: 120.0 + rng.f64() * 600.0,
        seed: rng.next_u64(),
    };
    TraceGenerator::new(cfg).generate()
}

fn random_ci(rng: &mut Rng) -> CarbonTrace {
    match rng.index(3) {
        0 => CarbonTrace::constant(100.0 + rng.f64() * 700.0),
        1 => synth_region(Region::SolarHeavy, 1, rng.next_u64()),
        _ => synth_region(Region::FossilHeavy, 1, rng.next_u64()),
    }
}

#[test]
fn counts_are_conserved() {
    forall("cold + warm == invocations", 25, 101, |rng| {
        let trace = random_trace(rng);
        let ci = random_ci(rng);
        let sim = Simulator::new(&trace, &ci, EnergyModel::default(), SimConfig::default());
        let m = sim.run(&mut FixedTimeout::new(*rng.choice(&[1.0, 10.0, 60.0]))).metrics;
        prop_assert!(
            m.cold_starts + m.warm_starts == m.invocations,
            "cold {} + warm {} != {}",
            m.cold_starts,
            m.warm_starts,
            m.invocations
        );
        prop_assert!(m.invocations as usize == trace.len(), "invocation count mismatch");
        Ok(())
    });
}

#[test]
fn carbon_components_nonnegative_and_sum() {
    forall("carbon components", 25, 102, |rng| {
        let trace = random_trace(rng);
        let ci = random_ci(rng);
        let sim = Simulator::new(&trace, &ci, EnergyModel::default(), SimConfig::default());
        let m = sim.run(&mut FixedTimeout::huawei()).metrics;
        prop_assert!(m.keepalive_carbon_g >= 0.0, "negative idle carbon");
        prop_assert!(m.exec_carbon_g > 0.0, "no exec carbon");
        prop_assert!(m.cold_carbon_g >= 0.0, "negative cold carbon");
        let sum = m.exec_carbon_g + m.keepalive_carbon_g + m.cold_carbon_g;
        prop_assert!(
            (m.total_carbon_g() - sum).abs() < 1e-9,
            "total != sum of components"
        );
        Ok(())
    });
}

#[test]
fn longer_timeout_monotone_tradeoff() {
    // Fig. 2's foundation: on any workload, a longer fixed keep-alive never
    // increases cold starts and never decreases idle pod-seconds.
    forall("timeout monotonicity", 20, 103, |rng| {
        let trace = random_trace(rng);
        let ci = random_ci(rng);
        let mut prev_cold = u64::MAX;
        let mut prev_idle = -1.0;
        for timeout in [1.0, 5.0, 10.0, 30.0, 60.0] {
            let sim =
                Simulator::new(&trace, &ci, EnergyModel::default(), SimConfig::default());
            let m = sim.run(&mut FixedTimeout::new(timeout)).metrics;
            prop_assert!(
                m.cold_starts <= prev_cold,
                "timeout {timeout}: cold starts increased {prev_cold} -> {}",
                m.cold_starts
            );
            prop_assert!(
                m.idle_pod_seconds >= prev_idle - 1e-9,
                "timeout {timeout}: idle seconds decreased"
            );
            prev_cold = m.cold_starts;
            prev_idle = m.idle_pod_seconds;
        }
        Ok(())
    });
}

#[test]
fn determinism_across_runs() {
    forall("simulation determinism", 15, 104, |rng| {
        let trace = random_trace(rng);
        let ci = random_ci(rng);
        let run = || {
            let sim =
                Simulator::new(&trace, &ci, EnergyModel::default(), SimConfig::default());
            sim.run(&mut FixedTimeout::huawei()).metrics
        };
        let a = run();
        let b = run();
        prop_assert!(a.cold_starts == b.cold_starts, "cold starts differ");
        prop_assert!(
            (a.total_carbon_g() - b.total_carbon_g()).abs() < 1e-12,
            "carbon differs"
        );
        prop_assert!(
            (a.avg_latency_s() - b.avg_latency_s()).abs() < 1e-12,
            "latency differs"
        );
        Ok(())
    });
}

#[test]
fn latency_bounded_by_components() {
    forall("latency bounds", 15, 105, |rng| {
        let trace = random_trace(rng);
        let ci = random_ci(rng);
        let cfg = SimConfig { track_latencies: true, ..SimConfig::default() };
        let sim = Simulator::new(&trace, &ci, EnergyModel::default(), cfg);
        let r = sim.run(&mut FixedTimeout::huawei());
        let max_cold = trace
            .functions
            .iter()
            .map(|f| f.cold_start_s)
            .fold(0.0f64, f64::max);
        let max_exec = trace
            .invocations
            .iter()
            .map(|i| i.exec_s)
            .fold(0.0f64, f64::max);
        for &l in &r.latencies {
            prop_assert!(l >= lace_rl::NETWORK_LATENCY_S, "latency below network floor");
            prop_assert!(
                l <= max_cold + max_exec + lace_rl::NETWORK_LATENCY_S + 1e-9,
                "latency {l} exceeds any possible path"
            );
        }
        Ok(())
    });
}

#[test]
fn oracle_never_wastes_more_idle_than_static() {
    // With perfect knowledge, the Oracle's keep-alive carbon can't exceed
    // the 60s static policy's: it keeps (span = gap ≤ static's span) or
    // drops (span = 1s minimum action).
    forall("oracle idle dominance", 15, 106, |rng| {
        let trace = random_trace(rng);
        let ci = random_ci(rng);
        let oracle_cfg = SimConfig { provide_oracle_gap: true, ..SimConfig::default() };
        let m_oracle = Simulator::new(&trace, &ci, EnergyModel::default(), oracle_cfg)
            .run(&mut Oracle)
            .metrics;
        let m_static = Simulator::new(&trace, &ci, EnergyModel::default(), SimConfig::default())
            .run(&mut FixedTimeout::new(60.0))
            .metrics;
        // vs the *refreshing* 60s timeout: the oracle keeps (span = gap ≤
        // the refresher's span) or drops (1s floor). The floor means oracle
        // can exceed only marginally; allow tolerance for that + CI wiggle.
        prop_assert!(
            m_oracle.keepalive_carbon_g <= m_static.keepalive_carbon_g * 1.05 + 1e-6,
            "oracle idle {} > static idle {}",
            m_oracle.keepalive_carbon_g,
            m_static.keepalive_carbon_g
        );
        Ok(())
    });
}

/// A concurrency-free workload: Poisson arrivals per function with
/// near-zero execution time, so pods never overlap and the per-decision
/// clairvoyant Oracle is the true per-function optimum. (On bursty
/// concurrent workloads the per-pod Oracle is *not* pool-optimal — see
/// Table III in EXPERIMENTS.md — so dominance is only a theorem here.)
fn serialized_trace(rng: &mut Rng) -> lace_rl::trace::model::Trace {
    use lace_rl::trace::model::{FunctionProfile, Invocation, Runtime, Trace, TriggerType};
    let n = 2 + rng.index(10);
    let duration = 500.0 + rng.f64() * 2_000.0;
    let functions: Vec<FunctionProfile> = (0..n)
        .map(|i| FunctionProfile {
            id: i as u32,
            runtime: Runtime::Python,
            trigger: TriggerType::Http,
            mem_mb: 32.0 + rng.f64() * 400.0,
            cpu_cores: 1.0,
            cold_start_s: 0.05 + rng.f64() * 10.0,
            mean_exec_s: 1e-4,
        })
        .collect();
    let mut invocations = Vec::new();
    for f in &functions {
        let gap = 1.0 + rng.f64() * 200.0;
        let mut t = rng.exp(1.0 / gap);
        while t < duration {
            invocations.push(Invocation { t, func: f.id, exec_s: 1e-4 });
            t += rng.exp(1.0 / gap).max(2e-4); // strictly serialized
        }
    }
    invocations.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
    Trace::new(functions, invocations)
}

#[test]
fn oracle_beats_static_on_blended_objective() {
    forall("oracle blended dominance", 10, 107, |rng| {
        let trace = serialized_trace(rng);
        if trace.is_empty() {
            return Ok(());
        }
        let ci = random_ci(rng);
        let lambda = 0.5;
        let cost = |m: &lace_rl::simulator::metrics::SimMetrics| {
            // Aggregate realized blended cost: cold-start seconds weighted
            // (1-λ), keep-alive grams weighted λκ.
            // Realized Eq. 5 aggregate: cold-start latency-seconds
            // weighted (1-λ), keep-alive grams weighted λκ — exactly the
            // objective the Oracle optimizes per decision.
            blended_cost(lambda, m.cold_latency_s, m.keepalive_carbon_g)
        };
        let oracle_cfg = SimConfig {
            lambda_carbon: lambda,
            provide_oracle_gap: true,
            ..SimConfig::default()
        };
        let m_oracle = Simulator::new(&trace, &ci, EnergyModel::default(), oracle_cfg)
            .run(&mut Oracle)
            .metrics;
        let m_static = Simulator::new(&trace, &ci, EnergyModel::default(), SimConfig::default())
            .run(&mut FixedTimeout::new(60.0))
            .metrics;
        // The oracle optimizes latency-seconds, not counts; counts are a
        // proxy, so allow slack.
        prop_assert!(
            cost(&m_oracle) <= cost(&m_static) * 1.25 + 1e-6,
            "oracle blended {} ≫ static {}",
            cost(&m_oracle),
            cost(&m_static)
        );
        Ok(())
    });
}
