#!/usr/bin/env bash
# Perf smoke: lint gates + a shrunken sim_throughput run that writes
# BENCH_sim.json (median ns + invocations/s per label). Run from anywhere;
# compares nothing itself — commit BENCH_sim.json deltas alongside perf PRs
# and eyeball the trajectory (EXPERIMENTS.md §Perf).
#
#   SKIP_LINT=1 scripts/bench_smoke.sh   # benches only, no fmt/clippy
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${SKIP_LINT:-0}" != "1" ]]; then
    echo "== cargo fmt --check =="
    cargo fmt --check
    echo "== cargo clippy (deny warnings) =="
    cargo clippy --all-targets -- -D warnings
fi

echo "== bench: sim_throughput --smoke =="
cargo bench --bench sim_throughput -- --smoke

if [[ -f BENCH_sim.json ]]; then
    echo "== BENCH_sim.json =="
    cat BENCH_sim.json
else
    echo "error: bench did not write BENCH_sim.json" >&2
    exit 1
fi

# Sharded replay must be a pure speedup: the same simulate run forced
# sequential (LACE_SIM_SHARDS=1) and sharded (=4) must print identical
# metrics, character for character.
echo "== sharded equivalence smoke (LACE_SIM_SHARDS 1 vs 4) =="
seq_out=$(LACE_SIM_SHARDS=1 cargo run --release --quiet --bin lace-rl -- simulate --quick --policy huawei)
par_out=$(LACE_SIM_SHARDS=4 cargo run --release --quiet --bin lace-rl -- simulate --quick --policy huawei)
if [[ "$seq_out" != "$par_out" ]]; then
    echo "error: sharded simulate output diverged from sequential" >&2
    diff <(echo "$seq_out") <(echo "$par_out") >&2 || true
    exit 1
fi
echo "$par_out"
echo "sharded output identical to sequential"
