#!/usr/bin/env bash
# Perf smoke: lint + doc gates plus shrunken sim_throughput and
# train_throughput runs that write BENCH_sim.json / BENCH_train.json
# (median ns + throughput per label). Run from anywhere; commit the
# BENCH_*.json deltas alongside perf PRs and eyeball the trajectory
# (EXPERIMENTS.md §Perf).
#
#   SKIP_LINT=1 scripts/bench_smoke.sh   # benches only, no fmt/clippy/doc
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${SKIP_LINT:-0}" != "1" ]]; then
    echo "== cargo fmt --check =="
    cargo fmt --check
    echo "== cargo clippy (deny warnings) =="
    cargo clippy --all-targets -- -D warnings
    echo "== cargo doc (deny warnings) =="
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
fi

# Remember the previous disabled-sink baseline before the bench overwrites
# BENCH_sim.json: the obs layer must not tax the hot path when it is off.
prev_fixed_ns=""
if [[ -f BENCH_sim.json ]]; then
    prev_fixed_ns=$(python3 - <<'EOF'
import json
try:
    doc = json.load(open("BENCH_sim.json"))
    entry = doc.get("benches", {}).get("sim/fixed-60s")
    if entry:
        print(entry["median_ns"])
except Exception:
    pass
EOF
)
fi

echo "== bench: sim_throughput --smoke =="
cargo bench --bench sim_throughput -- --smoke

if [[ -f BENCH_sim.json ]]; then
    echo "== BENCH_sim.json =="
    cat BENCH_sim.json
else
    echo "error: bench did not write BENCH_sim.json" >&2
    exit 1
fi

# Obs smoke: disabled-sink regression vs the previous baseline (warn-only;
# smoke boxes are noisy) and the enabled-collection overhead, both from the
# fresh BENCH_sim.json.
echo "== obs overhead check =="
PREV_FIXED_NS="$prev_fixed_ns" python3 - <<'EOF'
import json, os
doc = json.load(open("BENCH_sim.json"))
benches = doc.get("benches", {})
ns = {name: entry["median_ns"] for name, entry in benches.items()}
fixed, obs = ns.get("sim/fixed-60s"), ns.get("sim/fixed-60s-obs")
if fixed and obs:
    print(f"collection-on overhead: {100.0 * (obs / fixed - 1.0):+.1f}% "
          f"(sim/fixed-60s-obs vs sim/fixed-60s)")
prev = os.environ.get("PREV_FIXED_NS")
if prev and fixed:
    delta = 100.0 * (fixed / float(prev) - 1.0)
    print(f"disabled-sink delta vs previous BENCH_sim.json: {delta:+.1f}%")
    if delta > 2.0:
        print("warning: disabled-sink sim/fixed-60s regressed >2% — "
              "check the obs guards before merging")
EOF

# Train-step throughput: native always, PJRT rows when artifacts exist.
# The native-vs-PJRT agreement gate (params/loss ≤1e-5 over 100 shared
# minibatches) runs *inside* the bench binary and exits nonzero on
# divergence, so a wrong-but-fast step can never land a bench row.
echo "== bench: train_throughput --smoke =="
cargo bench --bench train_throughput -- --smoke

if [[ -f BENCH_train.json ]]; then
    echo "== BENCH_train.json =="
    cat BENCH_train.json
else
    echo "error: bench did not write BENCH_train.json" >&2
    exit 1
fi
python3 - <<'EOF'
import json
doc = json.load(open("BENCH_train.json"))
benches = doc.get("benches", {})
native = benches.get("train/step-native")
if not native:
    raise SystemExit("error: BENCH_train.json has no train/step-native row")
pjrt = benches.get("train/step-pjrt")
if pjrt:
    ratio = native["throughput_per_s"] / pjrt["throughput_per_s"]
    print(f"native/pjrt sample-throughput ratio: {ratio:.2f}x")
else:
    print("(no PJRT artifacts; native rows only)")
EOF

# Sharded replay must be a pure speedup: the same simulate run forced
# sequential (LACE_SIM_SHARDS=1) and sharded (=4) must print identical
# metrics, character for character.
echo "== sharded equivalence smoke (LACE_SIM_SHARDS 1 vs 4) =="
seq_out=$(LACE_SIM_SHARDS=1 cargo run --release --quiet --bin lace-rl -- simulate --quick --policy huawei)
par_out=$(LACE_SIM_SHARDS=4 cargo run --release --quiet --bin lace-rl -- simulate --quick --policy huawei)
if [[ "$seq_out" != "$par_out" ]]; then
    echo "error: sharded simulate output diverged from sequential" >&2
    diff <(echo "$seq_out") <(echo "$par_out") >&2 || true
    exit 1
fi
echo "$par_out"
echo "sharded output identical to sequential"

# Telemetry smoke: a quick experiment with --obs must emit parseable JSONL
# under results/obs/.
echo "== obs emission smoke (experiment fig5 --quick --obs) =="
cargo run --release --quiet --bin lace-rl -- experiment fig5 --quick --obs
python3 - <<'EOF'
import glob, json, sys
files = sorted(glob.glob("results/obs/*.jsonl"))
if not files:
    sys.exit("error: no JSONL streams under results/obs/")
for f in files:
    with open(f) as fh:
        n = 0
        for line in fh:
            json.loads(line)
            n += 1
    print(f"  {f}: {n} lines ok")
EOF
echo "obs streams parse clean"

# Chaos smoke: a quick serve under the canned fault plan must print a
# parseable CHAOS_SUMMARY with faults actually injected, and the summary —
# every counter and plan-derived field — must be bit-stable across reruns
# (the chaos determinism invariant, property-tested in
# rust/tests/property_chaos.rs; fault draws are virtual-time-keyed, so
# wall-clock pacing cannot perturb them).
echo "== chaos smoke (serve under canned plan, determinism gate) =="
chaos_a=$(cargo run --release --quiet --bin lace-rl -- chaos --quick --policy huawei)
chaos_b=$(cargo run --release --quiet --bin lace-rl -- chaos --quick --policy huawei)
sum_a=$(grep '^CHAOS_SUMMARY ' <<<"$chaos_a")
sum_b=$(grep '^CHAOS_SUMMARY ' <<<"$chaos_b")
if [[ -z "$sum_a" ]]; then
    echo "error: chaos run printed no CHAOS_SUMMARY line" >&2
    exit 1
fi
if [[ "$sum_a" != "$sum_b" ]]; then
    echo "error: CHAOS_SUMMARY not reproducible across identical runs" >&2
    diff <(echo "$sum_a") <(echo "$sum_b") >&2 || true
    exit 1
fi
CHAOS_SUMMARY_LINE="$sum_a" python3 - <<'EOF'
import json, os, sys
line = os.environ["CHAOS_SUMMARY_LINE"]
doc = json.loads(line.removeprefix("CHAOS_SUMMARY "))
keys = ["faults_injected", "spawn_retries", "retry_delay_s",
        "degraded_decisions", "stale_ci_decisions", "driver_stalls",
        "fallback_s"]
missing = [k for k in keys if k not in doc]
if missing:
    sys.exit(f"error: CHAOS_SUMMARY missing keys: {missing}")
if doc["faults_injected"] <= 0:
    sys.exit("error: canned full-intensity plan injected no faults")
print(f"  {line}")
EOF
echo "chaos summary parses clean and is reproducible"
