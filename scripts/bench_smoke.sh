#!/usr/bin/env bash
# Perf smoke: lint gates + a shrunken sim_throughput run that writes
# BENCH_sim.json (median ns + invocations/s per label). Run from anywhere;
# compares nothing itself — commit BENCH_sim.json deltas alongside perf PRs
# and eyeball the trajectory (EXPERIMENTS.md §Perf).
#
#   SKIP_LINT=1 scripts/bench_smoke.sh   # benches only, no fmt/clippy
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${SKIP_LINT:-0}" != "1" ]]; then
    echo "== cargo fmt --check =="
    cargo fmt --check
    echo "== cargo clippy (deny warnings) =="
    cargo clippy --all-targets -- -D warnings
fi

echo "== bench: sim_throughput --smoke =="
cargo bench --bench sim_throughput -- --smoke

if [[ -f BENCH_sim.json ]]; then
    echo "== BENCH_sim.json =="
    cat BENCH_sim.json
else
    echo "error: bench did not write BENCH_sim.json" >&2
    exit 1
fi
